"""Versioned schema of one recorded run — the trace plane's contract.

A :class:`Trace` is the canonical per-minibatch record of everything the
exact planes produce and the simulation plane prices: seeds, sampled
remote frontiers, miss sets (with their home-partition split), decisions
with validity/stall accounting, replacement admissions, byte counts and
per-PE step times — plus the event timeline when the run was priced by
the event engine. Two runs are "the same run" exactly when their traces
are bit-identical; every parity contract in the repo (legacy vs
vectorized, closed-form vs event, record vs replay) reduces to a trace
diff.

Layout: a dict of numpy arrays (the npz payload) plus a JSON manifest
(config, schema version, array specs, payload digest). All dtypes are
**normalized** so a trace recorded on one platform replays bit-identically
on another: node ids are always int64 (whatever dtype the producing
plane used — the int32 fast path of :class:`repro.graph.sampler.
SamplerPlane` and the int64 scalar path record identically), counters are
int64, times/fractions are float64, flags are bool.

Array families (S = steps, P = trainer PEs, E = epochs):

* dense per-step fields — ``(S, P)``, one value per (minibatch, PE):
  ``decisions, stalls, pct_hits, hits, n_remote, miss, replaced,
  total_comm, occupancy_pre, occupancy_post, step_time,
  valid_responses, invalid_responses`` (the last two are the cumulative
  Table-2 response counters of adaptive PEs);
* home-split matrices — ``(S, P, P)`` ``miss_pairs`` / ``repl_pairs``:
  ``[s, p, q]`` = nodes trainer p pulled from partition q at step s;
* feature-store measurements — optional ``(S, P)`` family present only
  when the run served real features (``--feature-store``):
  ``bytes_measured`` (bytes actually gathered), ``bytes_modeled`` (the
  time model's byte estimate for the same streams), ``feat_sums``
  (float64 content checksum of each PE's assembled remote feature
  block — makes shard corruption trace-visible), and
  ``fetch_time_measured`` (wall-clock gather seconds; the one
  nondeterministic field, excluded from exact comparisons);
* ragged id streams — ``<name>_flat`` int64 + ``<name>_offsets``
  ``(S * P + 1,)`` int64, segment ``(s, p)`` at flat offset
  ``s * P + p``: ``seeds, remote, miss_ids, placed_ids``;
* event timeline — parallel ``ev_*`` arrays mirroring
  :class:`repro.sim.events.SimEvent` tuples, with lane/kind interned
  against the manifest's code tables (present only for event-engine runs
  that collected events);
* run aggregates — ``epoch_times`` ``(E,)``.

The payload digest (sha256 over every array's name/dtype/shape/bytes) is
stored in the manifest: it makes "byte-stable" a one-line assert and
lets :func:`repro.trace.store.load_trace` detect corrupted or hand-edited
golden artifacts. The manifest ``config`` is carried for replay and
reporting but deliberately excluded from the digest — the same physical
run recorded under two configs (e.g. ``runtime=legacy`` vs
``vectorized``) must hash identically, that *is* the parity contract.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

#: Bump on any incompatible change to the array families or manifest
#: layout; ``load_trace`` refuses newer schemas and golden regeneration
#: is required after a bump (see docs/TESTING.md).
SCHEMA_VERSION = 1

#: Canonical dtype for node ids in every ragged stream.
ID_DTYPE = np.int64

#: Dense per-step fields: name -> canonical dtype.
STEP_FIELDS: dict[str, np.dtype] = {
    "decisions": np.dtype(bool),
    "stalls": np.dtype(np.float64),
    "pct_hits": np.dtype(np.float64),
    "hits": np.dtype(np.int64),
    "n_remote": np.dtype(np.int64),
    "miss": np.dtype(np.int64),
    "replaced": np.dtype(np.int64),
    "total_comm": np.dtype(np.int64),
    "occupancy_pre": np.dtype(np.float64),
    "occupancy_post": np.dtype(np.float64),
    "step_time": np.dtype(np.float64),
    "valid_responses": np.dtype(np.int64),
    "invalid_responses": np.dtype(np.int64),
}

#: Home-partition split matrices, (S, P, P) int64.
PAIR_FIELDS = ("miss_pairs", "repl_pairs")

#: Ragged per-(step, PE) id streams, stored as <name>_flat/<name>_offsets.
RAGGED_FIELDS = ("seeds", "remote", "miss_ids", "placed_ids")

#: Feature-store measurement fields, (S, P), present all-or-nothing and
#: only for store-enabled runs (schema stays v1 — the family is optional).
STORE_FIELDS: dict[str, np.dtype] = {
    "bytes_measured": np.dtype(np.int64),
    "bytes_modeled": np.dtype(np.int64),
    "feat_sums": np.dtype(np.float64),
    "fetch_time_measured": np.dtype(np.float64),
}

#: The deterministic "exact streams" a store-enabled run must reproduce
#: bit-identically against the modeled path: every dense step field
#: except the priced ``step_time``, the home-split matrices, and all
#: ragged id streams. ``Trace.exact_digest`` hashes exactly these.
EXACT_FIELDS: tuple[str, ...] = (
    tuple(n for n in STEP_FIELDS if n != "step_time")
    + PAIR_FIELDS
    + tuple(f"{n}_flat" for n in RAGGED_FIELDS)
    + tuple(f"{n}_offsets" for n in RAGGED_FIELDS)
)

#: Canonical event code tables (the ``repro.sim.events`` taxonomy).
#: ``ev_lane`` / ``ev_kind`` codes index into these, so the code arrays
#: are semantically stable across runs regardless of which event kinds a
#: particular run happens to emit first; unknown values are appended
#: after the canonical entries and the final tables land in the
#: manifest, where ``diff_traces`` compares them structurally.
LANES = ("compute", "net", "agent", "cluster")
KINDS = ("ddp", "fetch", "replace", "infer", "barrier")

#: Event-timeline arrays (parallel columns of SimEvent tuples).
EVENT_FIELDS: dict[str, np.dtype] = {
    "ev_step": np.dtype(np.int64),
    "ev_lane": np.dtype(np.int64),   # code into manifest["lanes"]
    "ev_kind": np.dtype(np.int64),   # code into manifest["kinds"]
    "ev_pe": np.dtype(np.int64),
    "ev_t0": np.dtype(np.float64),
    "ev_t1": np.dtype(np.float64),
    "ev_src": np.dtype(np.int64),
    "ev_nbytes": np.dtype(np.int64),
}


def normalize_ids(ids) -> np.ndarray:
    """One-dimensional int64 view of a node-id segment (any int dtype)."""
    arr = np.asarray(ids)
    if arr.ndim != 1:
        arr = arr.reshape(-1)
    return arr.astype(ID_DTYPE, copy=False)


@dataclass
class Trace:
    """One recorded run: JSON-able manifest + dict of numpy arrays."""

    manifest: dict
    arrays: dict[str, np.ndarray] = field(default_factory=dict)

    # ------------------------------------------------------------------ #
    @property
    def num_steps(self) -> int:
        return int(self.manifest["num_steps"])

    @property
    def num_pes(self) -> int:
        return int(self.manifest["num_pes"])

    @property
    def config(self) -> dict:
        return self.manifest.get("config", {})

    def ragged(self, name: str, step: int, pe: int) -> np.ndarray:
        """The ``(step, pe)`` segment of a ragged id stream."""
        offsets = self.arrays[f"{name}_offsets"]
        flat = self.arrays[f"{name}_flat"]
        k = step * self.num_pes + pe
        return flat[offsets[k] : offsets[k + 1]]

    # ------------------------------------------------------------------ #
    def digest(self, names=None) -> str:
        """sha256 over the array payload (name, dtype, shape, bytes).

        Deliberately config-independent: two traces with equal payloads
        hash equally even if recorded under different manifests — the
        cross-runtime byte-stability contract of ``tests/test_sim.py``.
        ``names`` restricts the hash to a field subset (sorted; missing
        names raise — a digest over absent fields is meaningless).
        """
        h = hashlib.sha256()
        for name in sorted(self.arrays) if names is None else sorted(names):
            arr = np.ascontiguousarray(self.arrays[name])
            h.update(name.encode())
            h.update(str(arr.dtype).encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    def exact_digest(self) -> str:
        """Digest of the deterministic exact streams (:data:`EXACT_FIELDS`).

        This is the measured-vs-modeled parity contract: a store-enabled
        run and the modeled-path golden of the same cell must agree here
        bit-exactly, while their full ``digest()`` differs (the store run
        carries the extra measurement family).
        """
        return self.digest(EXACT_FIELDS)

    def array_specs(self) -> dict[str, dict]:
        """Manifest rendering of the payload layout."""
        return {
            name: {"dtype": str(a.dtype), "shape": list(a.shape)}
            for name, a in sorted(self.arrays.items())
        }

    def validate(self) -> list[str]:
        """Schema conformance problems (empty list = sound trace)."""
        problems: list[str] = []
        m = self.manifest
        if m.get("schema_version") != SCHEMA_VERSION:
            problems.append(
                f"schema_version {m.get('schema_version')!r} != {SCHEMA_VERSION}"
            )
        S, P = self.num_steps, self.num_pes
        for name, dtype in STEP_FIELDS.items():
            arr = self.arrays.get(name)
            if arr is None:
                problems.append(f"missing field {name}")
            elif arr.shape != (S, P):
                problems.append(f"{name}: shape {arr.shape} != {(S, P)}")
            elif arr.dtype != dtype:
                problems.append(f"{name}: dtype {arr.dtype} != {dtype}")
        for name in PAIR_FIELDS:
            arr = self.arrays.get(name)
            if arr is not None and arr.shape != (S, P, P):
                problems.append(f"{name}: shape {arr.shape} != {(S, P, P)}")
        store_present = [n for n in STORE_FIELDS if n in self.arrays]
        if store_present and len(store_present) != len(STORE_FIELDS):
            missing = sorted(set(STORE_FIELDS) - set(store_present))
            problems.append(f"partial store family: missing {missing}")
        for name in store_present:
            arr = self.arrays[name]
            if arr.shape != (S, P):
                problems.append(f"{name}: shape {arr.shape} != {(S, P)}")
            elif arr.dtype != STORE_FIELDS[name]:
                problems.append(
                    f"{name}: dtype {arr.dtype} != {STORE_FIELDS[name]}"
                )
        for name in RAGGED_FIELDS:
            offsets = self.arrays.get(f"{name}_offsets")
            flat = self.arrays.get(f"{name}_flat")
            if offsets is None or flat is None:
                problems.append(f"missing ragged stream {name}")
                continue
            if offsets.shape != (S * P + 1,):
                problems.append(
                    f"{name}_offsets: shape {offsets.shape} != {(S * P + 1,)}"
                )
            elif offsets[0] != 0 or offsets[-1] != len(flat):
                problems.append(f"{name}: offsets do not span the flat array")
            elif (np.diff(offsets) < 0).any():
                problems.append(f"{name}: offsets not monotone")
            if flat is not None and flat.dtype != ID_DTYPE:
                problems.append(f"{name}_flat: dtype {flat.dtype} != {ID_DTYPE}")
        return problems


def canonical_manifest_json(manifest: dict) -> str:
    """Deterministic JSON rendering (sorted keys, no whitespace drift)."""
    return json.dumps(manifest, sort_keys=True, indent=1) + "\n"
