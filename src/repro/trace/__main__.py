"""Entry point: ``python -m repro.trace record|replay|diff|verify``."""

import sys

from .cli import main

if __name__ == "__main__":
    sys.exit(main())
