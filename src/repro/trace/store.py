"""Trace persistence: compressed npz payload + human-readable JSON manifest.

A trace on disk is two sibling files, ``<base>.npz`` (the arrays) and
``<base>.json`` (the manifest — config, schema version, array specs,
payload digest). The manifest is committed next to the payload under
``tests/golden/`` precisely because it is reviewable: a golden
regeneration shows up in the PR diff as changed digests and array
shapes, not as an opaque binary blob.

``load_trace`` verifies the payload digest by default, so a corrupted,
truncated or hand-edited golden fails loudly at load time rather than
producing a confusing diff downstream.
"""

from __future__ import annotations

import json
import os

import numpy as np

from .schema import SCHEMA_VERSION, Trace, canonical_manifest_json


def _base_path(path: str) -> str:
    for ext in (".npz", ".json"):
        if path.endswith(ext):
            return path[: -len(ext)]
    return path


def trace_paths(path: str) -> tuple[str, str]:
    """(npz_path, json_path) for any of base/.npz/.json spellings."""
    base = _base_path(path)
    return base + ".npz", base + ".json"


def save_trace(trace: Trace, path: str) -> tuple[str, str]:
    """Write ``<base>.npz`` + ``<base>.json``; returns both paths."""
    npz_path, json_path = trace_paths(path)
    directory = os.path.dirname(npz_path)
    if directory:
        os.makedirs(directory, exist_ok=True)
    manifest = dict(trace.manifest)
    manifest["arrays"] = trace.array_specs()
    manifest["digest"] = trace.digest()
    np.savez_compressed(npz_path, **trace.arrays)
    with open(json_path, "w") as fh:
        fh.write(canonical_manifest_json(manifest))
    return npz_path, json_path


def load_trace(path: str, verify: bool = True) -> Trace:
    """Load a trace; verifies schema version and payload digest."""
    npz_path, json_path = trace_paths(path)
    with open(json_path) as fh:
        manifest = json.load(fh)
    version = manifest.get("schema_version")
    if not isinstance(version, int) or version > SCHEMA_VERSION:
        raise ValueError(
            f"{json_path}: schema_version {version!r} is newer than this "
            f"reader ({SCHEMA_VERSION}); upgrade repro.trace"
        )
    with np.load(npz_path) as payload:
        arrays = {name: payload[name] for name in payload.files}
    trace = Trace(manifest=manifest, arrays=arrays)
    if verify:
        recorded = manifest.get("digest")
        actual = trace.digest()
        if recorded != actual:
            raise ValueError(
                f"{npz_path}: payload digest mismatch — file corrupted or "
                f"edited (manifest {recorded!r}, payload {actual!r}). "
                "Regenerate with tests/golden/regenerate.py if intentional."
            )
    return trace
