"""Replay adapters: feed a recorded upstream stream into one plane.

The point of the trace plane: any single plane can be regression-tested
against a recorded run *without re-running everything upstream of it*.

* :func:`replay_decisions` — re-run the decision plane
  (:class:`repro.runtime.DecisionStage` over fresh controllers) against
  the recorded probe-metric stream; returns the replayed
  decision/stall streams.
* :func:`replay_time_engine` — re-price the recorded miss/replacement
  streams (counts + home-partition splits) and stall ticks through any
  :class:`repro.sim.TimeEngine`; returns the replayed per-PE step times.

Each adapter has a ``*_report`` twin that diffs the replayed streams
against the recorded ones (bit-exact, first divergence located) — the
round-trip contract ``tests/test_trace.py`` asserts and the
``python -m repro.trace replay --plane=...`` CLI exposes.

The metrics reconstruction mirrors the runtimes exactly: ``comm_volume``
is the *pre-replacement* miss count, ``replaced_pct`` reads the previous
step's replacement count, ``buffer_occupancy`` is the probe-time
occupancy — see ``ProbeResult`` / the legacy loop in ``gnn/train.py``.
"""

from __future__ import annotations

import numpy as np

from .diff import DiffReport, diff_traces
from .schema import Trace


def metrics_at(trace: Trace, step: int):
    """The per-PE :class:`repro.core.metrics.Metrics` list of one step."""
    from ..core.metrics import Metrics

    m = trace.manifest
    P = trace.num_pes
    mb_per_epoch = int(m.get("mb_per_epoch") or 1)
    capacities = m.get("capacities") or [0] * P
    a = trace.arrays
    replaced_prev = a["replaced"][step - 1] if step > 0 else np.zeros(P)
    return [
        Metrics(
            minibatch=step % mb_per_epoch,
            total_minibatches=mb_per_epoch,
            epoch=step // mb_per_epoch,
            total_epochs=int(m.get("epochs") or 1),
            pct_hits=float(a["pct_hits"][step, p]),
            comm_volume=int(a["miss"][step, p]),
            replaced_pct=(
                100.0 * float(replaced_prev[p]) / capacities[p]
                if step > 0 and capacities[p]
                else 0.0
            ),
            buffer_occupancy=float(a["occupancy_pre"][step, p]),
            buffer_capacity=int(capacities[p]),
        )
        for p in range(P)
    ]


def replay_decisions(trace: Trace, controllers) -> tuple[np.ndarray, np.ndarray]:
    """Drive fresh controllers with the recorded metric stream.

    Returns ``(decisions (S, P) bool, stalls (S, P) float64)`` — the
    decision plane's full output under the recorded inputs. Controllers
    must be *fresh* (same construction as the recorded run); reusing the
    recorded run's controllers replays their mutated state, not the run.
    """
    from ..runtime.stage import DecisionStage

    S, P = trace.num_steps, trace.num_pes
    if len(controllers) != P:
        raise ValueError(f"expected {P} controllers, got {len(controllers)}")
    stage = DecisionStage(controllers)
    decisions = np.zeros((S, P), dtype=bool)
    stalls = np.zeros((S, P), dtype=np.float64)
    for s in range(S):
        stage.submit(metrics_at(trace, s))
        decisions[s], stalls[s] = stage.collect()
    return decisions, stalls


def replay_time_engine(trace: Trace, engine) -> np.ndarray:
    """Re-price the recorded communication streams through ``engine``.

    Builds one :class:`repro.sim.StepComm` per step from the recorded
    miss/replacement counts (and home-split matrices when the engine
    asks for them) and the recorded stall ticks; returns the replayed
    ``(S, P)`` step times. The engine must be fresh (one engine prices
    one run).
    """
    from ..sim import StepComm

    S, P = trace.num_steps, trace.num_pes
    a = trace.arrays
    if engine.needs_pairs and "miss_pairs" not in a:
        raise ValueError(
            "engine needs per-home pairs but the trace has no "
            "miss_pairs/repl_pairs (recorded without part_of)"
        )
    times = np.zeros((S, P), dtype=np.float64)
    for s in range(S):
        comm = StepComm(
            miss=a["miss"][s].astype(np.int64),
            repl=a["replaced"][s].astype(np.int64),
            miss_pairs=(
                a["miss_pairs"][s].astype(np.int64) if "miss_pairs" in a else None
            ),
            repl_pairs=(
                a["repl_pairs"][s].astype(np.int64) if "repl_pairs" in a else None
            ),
        )
        times[s] = engine.step(comm, a["stalls"][s])
    return times


# ---------------------------------------------------------------------- #
# report twins: replayed streams vs recorded streams, bit-exact
# ---------------------------------------------------------------------- #
def _with_arrays(trace: Trace, **overrides) -> Trace:
    return Trace(
        manifest=trace.manifest, arrays={**trace.arrays, **overrides}
    )


def replay_decisions_report(trace: Trace, controllers) -> DiffReport:
    """Replay the decision plane and diff decisions/stalls vs recorded."""
    decisions, stalls = replay_decisions(trace, controllers)
    replayed = _with_arrays(trace, decisions=decisions, stalls=stalls)
    return diff_traces(trace, replayed, fields=("decisions", "stalls"))


def replay_time_engine_report(trace: Trace, engine) -> DiffReport:
    """Replay the time engine and diff step times vs recorded."""
    times = replay_time_engine(trace, engine)
    replayed = _with_arrays(trace, step_time=times)
    return diff_traces(trace, replayed, fields=("step_time",))
