"""Trace capture — the recorder both runtimes feed per minibatch.

One :class:`TraceRecorder` records one run. The runtimes call
:meth:`record_step` once per minibatch with the exact streams they just
produced (guarded by ``if recorder is not None`` — zero work when
tracing is off) and :meth:`finalize` once at the end; the result is a
schema-conformant :class:`repro.trace.schema.Trace`.

The recorder never *computes* anything the run didn't — it normalizes
dtypes (ids to int64, counters to int64, times to float64) and derives
only the home-partition split matrices (one bincount per stream, the
same arithmetic as :func:`repro.sim.build_step_comm`), so recording with
either runtime yields bit-identical payloads — the contract
``tests/test_trace.py`` asserts for all four controller variants in
both queue modes.
"""

from __future__ import annotations

import numpy as np

from .schema import (
    ID_DTYPE,
    RAGGED_FIELDS,
    SCHEMA_VERSION,
    STEP_FIELDS,
    STORE_FIELDS,
    Trace,
    normalize_ids,
)


def controller_validity(controllers) -> tuple[np.ndarray, np.ndarray]:
    """Cumulative (valid, invalid) response counters per PE (Table 2).

    Adaptive PEs report their agent's ``DecisionMaker`` counters;
    heuristic controllers (and classifier deciders, which never produce
    malformed responses) report zeros.
    """
    P = len(controllers)
    valid = np.zeros(P, dtype=np.int64)
    invalid = np.zeros(P, dtype=np.int64)
    for p, ctrl in enumerate(controllers):
        agent = getattr(ctrl, "agent", None)
        maker = getattr(agent, "maker", None)
        if maker is not None:
            valid[p] = int(maker.valid_responses)
            invalid[p] = int(maker.invalid_responses)
    return valid, invalid


def _pairs_of(node_lists, part_of: np.ndarray, P: int, id_base: int = 0) -> np.ndarray:
    """(P, P) home-partition split of per-PE node-id lists (one bincount,
    keyed ``trainer_row * P + home`` — mirrors ``sim.build_step_comm``).
    Ids are global; ``part_of`` is local-indexed, hence the ``id_base``
    rebase before the home lookup."""
    lengths = [len(x) for x in node_lists]
    rows = np.repeat(np.arange(P, dtype=np.int64), lengths)
    nodes = (
        np.concatenate([normalize_ids(x) for x in node_lists])
        if sum(lengths)
        else np.array([], dtype=ID_DTYPE)
    )
    return np.bincount(
        rows * P + part_of[nodes - id_base], minlength=P * P
    ).reshape(P, P)


class TraceRecorder:
    """Accumulates one run's per-step streams; finalize() -> Trace."""

    def __init__(
        self,
        num_pes: int,
        part_of: np.ndarray | None = None,
        config: dict | None = None,
        capacities=None,
        feature_dim: int = 0,
        feature_bytes: int = 4,
        mb_per_epoch: int = 0,
        epochs: int = 0,
        mode: str = "async",
        variant: str = "",
        id_base: int = 0,
    ):
        self.num_pes = int(num_pes)
        self.part_of = part_of
        self.id_base = int(id_base)
        self.config = dict(config) if config else {}
        self.capacities = [int(c) for c in capacities] if capacities is not None else []
        self.feature_dim = int(feature_dim)
        self.feature_bytes = int(feature_bytes)
        self.mb_per_epoch = int(mb_per_epoch)
        self.epochs = int(epochs)
        self.mode = mode
        self.variant = variant
        self._steps: list[dict] = []
        self._ragged: dict[str, list[np.ndarray]] = {n: [] for n in RAGGED_FIELDS}
        self._has_store: bool | None = None  # set by the first record_step
        self._finalized = False

    # ------------------------------------------------------------------ #
    @classmethod
    def for_trainer(cls, trainer, config: dict | None = None) -> "TraceRecorder":
        """Build a recorder wired to a :class:`DistributedTrainer`.

        ``config`` is the manifest config; when the trainer was built by
        the trace CLI / sweep runner this is the full replayable cell
        config. Otherwise (``DistributedTrainer(trace=True)``) it is a
        best-effort summary of the trainer's axes marked
        ``replayable: False`` — the graph's generation scale/seed and
        the deciders are not recoverable from a live trainer, so CLI
        ``replay`` refuses to rebuild from it (the in-process replay
        adapters, which take the trainer's own objects, are unaffected).
        """
        if config is None:
            config = {
                "dataset": trainer.graph.name,
                "variant": trainer.variant,
                "num_parts": int(trainer.parts.num_parts),
                "batch_size": int(trainer.batch_size),
                "fanouts": [int(f) for f in trainer.sampler.fanouts],
                "buffer_frac": float(trainer.buffer_frac),
                "mode": trainer.mode,
                "epochs": int(trainer.epochs),
                "policy": trainer.policy.name,
                "time_engine": trainer.time_engine,
                "replayable": False,
            }
        return cls(
            num_pes=trainer.parts.num_parts,
            part_of=trainer.parts.part_of,
            config=config,
            capacities=[int(c) for c in trainer.engine.capacity],
            feature_dim=int(trainer.graph.features.shape[1]),
            feature_bytes=int(trainer.tm.feature_bytes),
            mb_per_epoch=trainer.mb_per_epoch,
            epochs=trainer.epochs,
            mode=trainer.mode,
            variant=trainer.variant,
            id_base=int(trainer.graph.id_base),
        )

    # ------------------------------------------------------------------ #
    def record_step(
        self,
        *,
        seeds,
        remote,
        missed,
        placed,
        decisions,
        stalls,
        pct_hits,
        hits,
        n_remote,
        replaced,
        total_comm,
        occupancy_pre,
        occupancy_post,
        step_times,
        controllers=None,
        feat_sums=None,
        bytes_measured=None,
        bytes_modeled=None,
        fetch_time_measured=None,
    ) -> None:
        """Record one minibatch: per-PE id lists + dense per-PE streams.

        The feature-store measurement family (``feat_sums``,
        ``bytes_measured``, ``bytes_modeled``, ``fetch_time_measured``)
        is all-or-nothing — pass all four ``(P,)`` streams on every step
        of a store-enabled run, or none on any step.

        Validates *every* argument before mutating any recorder state,
        so a rejected call leaves the recorder unchanged (a caller that
        catches the error and retries does not corrupt the step/segment
        alignment).
        """
        if self._finalized:
            raise RuntimeError("recorder already finalized")
        P = self.num_pes
        store_in = {
            "feat_sums": feat_sums,
            "bytes_measured": bytes_measured,
            "bytes_modeled": bytes_modeled,
            "fetch_time_measured": fetch_time_measured,
        }
        given = [n for n, v in store_in.items() if v is not None]
        if given and len(given) != len(store_in):
            missing = sorted(set(store_in) - set(given))
            raise ValueError(f"partial store family: missing {missing}")
        has_store = bool(given)
        if self._has_store is not None and has_store != self._has_store:
            raise ValueError(
                "store fields must be recorded on every step or none"
            )
        ragged_in = {
            "seeds": seeds,
            "remote": remote,
            "miss_ids": missed,
            "placed_ids": placed,
        }
        for name, lists in ragged_in.items():
            if len(lists) != P:
                raise ValueError(f"{name}: expected {P} per-PE lists, got {len(lists)}")
        valid, invalid = (
            controller_validity(controllers)
            if controllers is not None
            else (np.zeros(P, dtype=np.int64), np.zeros(P, dtype=np.int64))
        )
        row = {
            "decisions": np.asarray(decisions, dtype=bool),
            "stalls": np.asarray(stalls, dtype=np.float64),
            "pct_hits": np.asarray(pct_hits, dtype=np.float64),
            "hits": np.asarray(hits, dtype=np.int64),
            "n_remote": np.asarray(n_remote, dtype=np.int64),
            "miss": np.array([len(m) for m in missed], dtype=np.int64),
            "replaced": np.asarray(replaced, dtype=np.int64),
            "total_comm": np.asarray(total_comm, dtype=np.int64),
            "occupancy_pre": np.asarray(occupancy_pre, dtype=np.float64),
            "occupancy_post": np.asarray(occupancy_post, dtype=np.float64),
            "step_time": np.asarray(step_times, dtype=np.float64),
            "valid_responses": valid,
            "invalid_responses": invalid,
        }
        if has_store:
            for name, value in store_in.items():
                row[name] = np.asarray(value, dtype=STORE_FIELDS[name])
        for name, arr in row.items():
            if arr.shape != (P,):
                raise ValueError(f"{name}: shape {arr.shape} != ({P},)")
        if self.part_of is not None:
            row["miss_pairs"] = _pairs_of(missed, self.part_of, P, self.id_base)
            row["repl_pairs"] = _pairs_of(placed, self.part_of, P, self.id_base)
        # Everything validated — mutate atomically.
        self._has_store = has_store
        for name, lists in ragged_in.items():
            self._ragged[name].extend(normalize_ids(x) for x in lists)
        self._steps.append(row)

    # ------------------------------------------------------------------ #
    def finalize(self, epoch_times, events=None) -> Trace:
        """Close the run: stack streams, intern events, build the manifest."""
        if self._finalized:
            raise RuntimeError("recorder already finalized")
        self._finalized = True
        S, P = len(self._steps), self.num_pes
        arrays: dict[str, np.ndarray] = {}
        for name, dtype in STEP_FIELDS.items():
            arrays[name] = (
                np.stack([row[name] for row in self._steps])
                if S
                else np.zeros((0, P), dtype=dtype)
            ).astype(dtype)
        if self.part_of is not None:
            for name in ("miss_pairs", "repl_pairs"):
                arrays[name] = (
                    np.stack([row[name] for row in self._steps])
                    if S
                    else np.zeros((0, P, P), dtype=np.int64)
                ).astype(np.int64)
        if self._has_store:
            for name, dtype in STORE_FIELDS.items():
                arrays[name] = np.stack(
                    [row[name] for row in self._steps]
                ).astype(dtype)
        for name, segments in self._ragged.items():
            lengths = np.array([len(s) for s in segments], dtype=np.int64)
            arrays[f"{name}_offsets"] = np.concatenate(
                [[0], np.cumsum(lengths)]
            ).astype(np.int64)
            arrays[f"{name}_flat"] = (
                np.concatenate(segments) if segments else np.array([], dtype=ID_DTYPE)
            ).astype(ID_DTYPE)
        arrays["epoch_times"] = np.asarray(list(epoch_times), dtype=np.float64)

        from .schema import KINDS, LANES

        lanes: list[str] = list(LANES)
        kinds: list[str] = list(KINDS)
        if events is not None and len(events):
            rows = events.as_tuples()

            def intern(table: list[str], value: str) -> int:
                if value not in table:
                    table.append(value)
                return table.index(value)

            arrays["ev_step"] = np.array([r[0] for r in rows], dtype=np.int64)
            arrays["ev_lane"] = np.array(
                [intern(lanes, r[1]) for r in rows], dtype=np.int64
            )
            arrays["ev_kind"] = np.array(
                [intern(kinds, r[2]) for r in rows], dtype=np.int64
            )
            arrays["ev_pe"] = np.array([r[3] for r in rows], dtype=np.int64)
            arrays["ev_t0"] = np.array([r[4] for r in rows], dtype=np.float64)
            arrays["ev_t1"] = np.array([r[5] for r in rows], dtype=np.float64)
            arrays["ev_src"] = np.array([r[6] for r in rows], dtype=np.int64)
            arrays["ev_nbytes"] = np.array([r[7] for r in rows], dtype=np.int64)

        manifest = {
            "schema_version": SCHEMA_VERSION,
            "config": self.config,
            "num_steps": S,
            "num_pes": P,
            "mb_per_epoch": self.mb_per_epoch,
            "epochs": self.epochs,
            "mode": self.mode,
            "variant": self.variant,
            "capacities": self.capacities,
            "feature_dim": self.feature_dim,
            "feature_bytes": self.feature_bytes,
            "id_dtype": str(np.dtype(ID_DTYPE)),
            "has_pairs": self.part_of is not None,
            "feature_store": bool(self._has_store),
            "lanes": lanes,
            "kinds": kinds,
        }
        trace = Trace(manifest=manifest, arrays=arrays)
        manifest["arrays"] = trace.array_specs()
        manifest["digest"] = trace.digest()
        return trace
