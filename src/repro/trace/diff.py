"""Trace comparison: structured first-divergence reports.

``diff_traces(a, b)`` compares two traces field by field and reports,
for every diverging field, the **first** diverging element located in
run coordinates — ``(field, step, pe, value_a, value_b)`` — plus any
structural problems (missing fields, shape mismatches). This is what
turns "the parity contract broke" from a failing assert into an
actionable artifact: the CI golden gate uploads the JSON rendering next
to the bench artifacts, and ``python -m repro.trace diff`` prints the
human rendering.

Equality is **bit-exact** (NaN == NaN, so a NaN-on-empty aggregate does
not read as drift). Manifest config differences are reported separately
and do not affect :attr:`DiffReport.identical` — the same physical run
recorded under two configs (legacy vs vectorized runtime) must diff
clean; that *is* the cross-runtime contract.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from .schema import RAGGED_FIELDS, Trace


@dataclass(frozen=True)
class Divergence:
    """First diverging element of one field, in run coordinates."""

    field: str
    step: int       # minibatch step (-1 for non-step arrays)
    pe: int         # trainer PE (-1 when not PE-indexed)
    index: int      # flat index within the field
    a: object
    b: object

    def render(self) -> str:
        where = f"step={self.step} pe={self.pe}" if self.step >= 0 else f"i={self.index}"
        return f"{self.field} [{where}]: {self.a!r} != {self.b!r}"


@dataclass
class DiffReport:
    """Outcome of one trace comparison."""

    divergences: list[Divergence] = field(default_factory=list)
    problems: list[str] = field(default_factory=list)
    config_mismatches: list[str] = field(default_factory=list)

    @property
    def identical(self) -> bool:
        return not self.divergences and not self.problems

    @property
    def first(self) -> Divergence | None:
        return self.divergences[0] if self.divergences else None

    def render(self) -> str:
        if self.identical:
            return "identical"
        lines = [f"PROBLEM: {p}" for p in self.problems]
        lines += [d.render() for d in self.divergences]
        return "\n".join(lines)

    def to_json(self) -> dict:
        def plain(v):
            if isinstance(v, (np.integer,)):
                return int(v)
            if isinstance(v, (np.floating,)):
                return float(v)
            if isinstance(v, (np.bool_,)):
                return bool(v)
            return v

        return {
            "identical": self.identical,
            "problems": list(self.problems),
            "config_mismatches": list(self.config_mismatches),
            "divergences": [
                {
                    "field": d.field,
                    "step": d.step,
                    "pe": d.pe,
                    "index": d.index,
                    "a": plain(d.a),
                    "b": plain(d.b),
                }
                for d in self.divergences
            ],
        }


def _exact_equal(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise bit-exact equality with NaN == NaN."""
    eq = a == b
    if a.dtype.kind == "f" and b.dtype.kind == "f":
        eq = eq | (np.isnan(a) & np.isnan(b))
    return eq


def _first_divergence(
    name: str, a: np.ndarray, b: np.ndarray, num_pes: int
) -> Divergence | None:
    eq = _exact_equal(a, b)
    if eq.all():
        return None
    flat = int(np.argmin(eq.ravel()))
    step, pe = -1, -1
    if a.ndim >= 2 and a.shape[1] == num_pes and not name.startswith("ev_"):
        per_step = int(np.prod(a.shape[1:]))
        step = flat // per_step
        pe = (flat % per_step) // (per_step // num_pes)
    elif name.startswith("ev_"):
        step = -1
    return Divergence(
        field=name, step=step, pe=pe, index=flat,
        a=a.ravel()[flat], b=b.ravel()[flat],
    )


def _canonical_segments(flat: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat stream with every (step, pe) segment sorted ascending."""
    seg = np.repeat(np.arange(len(lens), dtype=np.int64), lens)
    return flat[np.lexsort((flat, seg))]


def _diff_ragged(
    name: str, a: Trace, b: Trace, report: DiffReport
) -> None:
    """Compare one ragged stream; locate divergence as (step, pe).

    Id sets inside a segment are compared **canonically** (each segment
    sorted) before element positions are blamed: two streams holding the
    same ids in different orders used to report the first positional
    mismatch as a content divergence — misleading, since the first
    *genuinely different id* may sit steps later (or nowhere). Now an
    order-only difference reports as ``<name>.order`` at the first
    raw-mismatching segment, and a content difference is located in the
    canonical stream, naming an id actually present in only one trace.
    """
    P = a.num_pes
    off_a, off_b = a.arrays[f"{name}_offsets"], b.arrays[f"{name}_offsets"]
    flat_a, flat_b = a.arrays[f"{name}_flat"], b.arrays[f"{name}_flat"]
    if off_a.shape != off_b.shape:
        report.problems.append(
            f"{name}: segment count {off_a.shape[0] - 1} != {off_b.shape[0] - 1}"
        )
        return
    lens_a, lens_b = np.diff(off_a), np.diff(off_b)
    if not np.array_equal(lens_a, lens_b):
        k = int(np.argmin(lens_a == lens_b))
        report.divergences.append(Divergence(
            field=f"{name}.len", step=k // P, pe=k % P, index=k,
            a=int(lens_a[k]), b=int(lens_b[k]),
        ))
        return
    eq = _exact_equal(flat_a, flat_b)
    if eq.all():
        return
    can_a = _canonical_segments(flat_a, lens_a)
    can_b = _canonical_segments(flat_b, lens_b)
    can_eq = _exact_equal(can_a, can_b)
    if can_eq.all():
        # Same id sets everywhere — ordering drift only. Blame the first
        # segment whose raw layout differs.
        flat = int(np.argmin(eq))
        k = int(np.searchsorted(off_a, flat, side="right")) - 1
        report.divergences.append(Divergence(
            field=f"{name}.order", step=k // P, pe=k % P, index=flat,
            a=flat_a[flat], b=flat_b[flat],
        ))
        return
    flat = int(np.argmin(can_eq))
    k = int(np.searchsorted(off_a, flat, side="right")) - 1
    report.divergences.append(Divergence(
        field=name, step=k // P, pe=k % P, index=flat,
        a=can_a[flat], b=can_b[flat],
    ))


def diff_traces(a: Trace, b: Trace, fields=None) -> DiffReport:
    """Compare two traces; returns the structured report.

    ``fields`` restricts the comparison (used by the replay adapters to
    check only the streams a single plane reproduces). Divergences are
    ordered by (step, field) so the report leads with the earliest drift.
    """
    report = DiffReport()
    # lanes/kinds decode the ev_lane/ev_kind code arrays: a table
    # mismatch means equal codes name different events, so it is a
    # structural problem, not a config note.
    for key in ("schema_version", "num_steps", "num_pes", "lanes", "kinds"):
        if a.manifest.get(key) != b.manifest.get(key):
            report.problems.append(
                f"manifest.{key}: {a.manifest.get(key)!r} != {b.manifest.get(key)!r}"
            )
    if report.problems:
        return report
    ca, cb = a.config, b.config
    for key in sorted(set(ca) | set(cb)):
        if ca.get(key) != cb.get(key):
            report.config_mismatches.append(
                f"config.{key}: {ca.get(key)!r} != {cb.get(key)!r}"
            )

    ragged_wanted = [
        n for n in RAGGED_FIELDS
        if fields is None or n in fields
    ]
    ragged_keys = {
        f"{n}_{suffix}" for n in RAGGED_FIELDS for suffix in ("flat", "offsets")
    }
    names_a = set(a.arrays) - ragged_keys
    names_b = set(b.arrays) - ragged_keys
    if fields is not None:
        names_a &= set(fields)
        names_b &= set(fields)
    for name in sorted(names_a ^ names_b):
        report.problems.append(
            f"{name}: present only in {'a' if name in names_a else 'b'}"
        )
    for name in sorted(names_a & names_b):
        arr_a, arr_b = np.asarray(a.arrays[name]), np.asarray(b.arrays[name])
        if arr_a.shape != arr_b.shape:
            report.problems.append(
                f"{name}: shape {arr_a.shape} != {arr_b.shape}"
            )
            continue
        div = _first_divergence(name, arr_a, arr_b, a.num_pes)
        if div is not None:
            report.divergences.append(div)
    for name in ragged_wanted:
        in_a = f"{name}_flat" in a.arrays
        in_b = f"{name}_flat" in b.arrays
        if in_a and in_b:
            _diff_ragged(name, a, b, report)
        elif in_a != in_b:
            report.problems.append(
                f"{name}: ragged stream present only in {'a' if in_a else 'b'}"
            )
    report.divergences.sort(key=lambda d: (d.step if d.step >= 0 else 1 << 60, d.field))
    return report


def write_report_json(report: DiffReport, path: str, extra: dict | None = None):
    """Write the JSON rendering (the CI gate's uploaded artifact)."""
    payload = report.to_json()
    if extra:
        payload.update(extra)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return payload
