"""``python -m repro.trace`` — record / replay / diff / verify.

Subcommands::

    record  --out PATH [axis flags]     run one configuration, save trace
    replay  PATH [--plane ...]          rebuild from the manifest config,
                                        replay, diff vs recorded
    diff    A B                         structured first-divergence report
    verify  DIR [--json PATH]           re-record every golden in DIR and
                                        diff (the CI drift gate)

``record`` writes a *replayable* manifest: the full cell config (same
axes as the sweep grid) is stored under ``manifest["config"]``, so
``replay`` can rebuild the trainer exactly. ``replay --plane`` selects
what is re-run: ``full`` re-records the whole run (both runtimes via
``--runtime``), ``decision`` re-runs only the decision plane against the
recorded metric stream, ``time`` re-prices the recorded communication
streams through a fresh time engine. Exit status 1 on any divergence —
every subcommand is CI-gate shaped.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .capture import TraceRecorder
from .diff import DiffReport, diff_traces, write_report_json
from .replay import replay_decisions_report, replay_time_engine_report
from .schema import RAGGED_FIELDS, Trace
from .store import load_trace, save_trace, trace_paths

#: The replayable cell config: same axes as ``runtime.sweep.SweepConfig``
#: plus ``scale`` and ``runtime`` (which the sweep fixes globally).
CONFIG_DEFAULTS: dict = {
    "dataset": "products",
    "scale": 0.12,
    "variant": "fixed",
    "num_parts": 4,
    "batch_size": 16,
    "fanouts": [10, 25],
    "mode": "async",
    "interval": 32,
    "buffer_frac": 0.25,
    "epochs": 3,
    "backend": "gemma3-4b",
    "policy": "rudder",
    "topology": "none",
    "time_engine": "closed_form",
    "stragglers": "none",
    "congestion": "none",
    "seed": 0,
    "runtime": "vectorized",
    "feature_store": False,
    "device": False,
}


def _parse_bool(s: str) -> bool:
    """argparse-safe bool: ``type=bool`` would make ``--x false`` True."""
    if s.lower() in ("1", "true", "yes", "on"):
        return True
    if s.lower() in ("0", "false", "no", "off"):
        return False
    raise argparse.ArgumentTypeError(f"expected a boolean, got {s!r}")


def build_trainer(config: dict, runtime: str | None = None, parts=None):
    """Construct the :class:`DistributedTrainer` a trace config names.

    The **single** config-to-trainer builder: the trace CLI and the
    sweep runner (``runtime.sweep.run_sweep``) both construct cells
    through here, so a replayable manifest always rebuilds exactly the
    trainer that recorded it. ``parts`` supplies a pre-partitioned graph
    (the sweep's partition cache); otherwise the graph is generated from
    ``(dataset, scale, seed)`` and partitioned ``num_parts``-way.
    Experiment cells never train the model (``train_model=False``).
    """
    from ..core import LLMAgent, make_backend
    from ..gnn import DistributedTrainer

    cfg = {**CONFIG_DEFAULTS, **config}
    if parts is None:
        from ..graph import generate, partition_graph

        g = generate(
            cfg["dataset"], seed=int(cfg["seed"]), scale=float(cfg["scale"])
        )
        parts = partition_graph(g, int(cfg["num_parts"]))
    deciders = None
    if cfg["variant"] == "rudder":
        deciders = [
            LLMAgent(make_backend(cfg["backend"]), None)
            for _ in range(int(cfg["num_parts"]))
        ]
    return DistributedTrainer(
        parts,
        variant=cfg["variant"],
        deciders=deciders,
        buffer_frac=float(cfg["buffer_frac"]),
        batch_size=int(cfg["batch_size"]),
        fanouts=tuple(int(f) for f in cfg["fanouts"]),
        epochs=int(cfg["epochs"]),
        mode=cfg["mode"],
        interval=int(cfg["interval"]),
        policy=cfg["policy"],
        topology=None if cfg["topology"] == "none" else cfg["topology"],
        time_engine=cfg["time_engine"],
        stragglers=cfg["stragglers"],
        congestion=cfg["congestion"],
        train_model=False,
        seed=int(cfg["seed"]),
        runtime=runtime or cfg.get("runtime", "vectorized"),
        feature_store=bool(cfg["feature_store"]),
        device=cfg["device"],
    )


def record_trace(config: dict, runtime: str | None = None) -> Trace:
    """Run one configuration with capture on; returns the finished trace."""
    cfg = {**CONFIG_DEFAULTS, **config}
    if runtime:
        cfg["runtime"] = runtime
    trainer = build_trainer(cfg)
    trainer.trace = TraceRecorder.for_trainer(trainer, config=cfg)
    trainer.run()
    return trainer.last_trace


# ---------------------------------------------------------------------- #
def _emit(report: DiffReport, json_path: str | None, extra: dict | None = None) -> int:
    print(report.render())
    if json_path:
        write_report_json(report, json_path, extra)
        print(f"# report written to {json_path}", file=sys.stderr)
    return 0 if report.identical else 1


def cmd_record(args) -> int:
    config = {
        key: getattr(args, key)
        for key in CONFIG_DEFAULTS
        if getattr(args, key, None) is not None
    }
    trace = record_trace(config)
    npz_path, json_path = save_trace(trace, args.out)
    print(
        f"recorded {trace.num_steps} steps x {trace.num_pes} PEs "
        f"-> {npz_path} + {json_path} (digest {trace.digest()[:12]})"
    )
    return 0


def cmd_replay(args) -> int:
    trace = load_trace(args.trace)
    config = trace.config
    if not config.get("replayable", True):
        print(
            f"{args.trace}: manifest config is not replayable — the trace "
            "was recorded from a live trainer (DistributedTrainer("
            "trace=True)), whose graph scale/seed and deciders are not "
            "recoverable. Record via `python -m repro.trace record` or a "
            "sweep --trace=DIR for a rebuildable manifest, or use the "
            "in-process replay adapters (repro.trace.replay).",
            file=sys.stderr,
        )
        return 2
    if args.plane == "full":
        fresh = record_trace(config, runtime=args.runtime)
        fields = None
        if "fetch_time_measured" in trace.arrays:
            # Store-enabled trace: the wall-clock measurement is
            # nondeterministic by design (the one field excluded from
            # Trace.exact_digest()), so a full replay compares every
            # stream except it — otherwise replay could never come back
            # identical.
            ragged_keys = {
                f"{n}_{s}"
                for n in RAGGED_FIELDS
                for s in ("flat", "offsets")
            }
            fields = sorted(
                ((set(trace.arrays) | set(fresh.arrays)) - ragged_keys
                 - {"fetch_time_measured"}) | set(RAGGED_FIELDS)
            )
            print(
                "# note: fetch_time_measured (wall clock) excluded "
                "from the replay diff",
                file=sys.stderr,
            )
        report = diff_traces(trace, fresh, fields=fields)
    elif args.plane == "decision":
        trainer = build_trainer(config, runtime=args.runtime)
        report = replay_decisions_report(trace, trainer.controllers)
    elif args.plane == "time":
        trainer = build_trainer(config, runtime=args.runtime)
        report = replay_time_engine_report(trace, trainer.make_time_engine())
    else:  # pragma: no cover — argparse choices guard this
        raise ValueError(args.plane)
    return _emit(report, args.json, {"trace": args.trace, "plane": args.plane})


def cmd_diff(args) -> int:
    report = diff_traces(load_trace(args.a), load_trace(args.b))
    for note in report.config_mismatches:
        print(f"# note: {note}", file=sys.stderr)
    return _emit(report, args.json, {"a": args.a, "b": args.b})


def cmd_verify(args) -> int:
    """Re-record every golden under DIR and diff — the CI drift gate."""
    # Every trace manifest (any JSON with a schema_version) is in scope;
    # an orphan manifest whose npz payload is missing must FAIL the
    # gate, not silently shrink the conformance set.
    manifests: list[str] = []
    for fname in sorted(os.listdir(args.dir)):
        if not fname.endswith(".json"):
            continue
        try:
            with open(os.path.join(args.dir, fname)) as fh:
                manifest = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        if isinstance(manifest, dict) and "schema_version" in manifest:
            manifests.append(fname)
    if not manifests:
        print(f"no traces found under {args.dir}", file=sys.stderr)
        return 2
    results: dict[str, dict] = {}
    failed = 0
    for name in manifests:
        base = os.path.join(args.dir, name)
        npz_path, _ = trace_paths(base)
        if not os.path.exists(npz_path):
            report = DiffReport(
                problems=[
                    f"{name}: payload {os.path.basename(npz_path)} missing"
                ]
            )
        else:
            # Any per-golden failure (digest/schema ValueError, a
            # truncated npz's BadZipFile, a re-record crash) must land
            # in the report and fail the gate — never take down the
            # whole verify run with the JSON artifact unwritten.
            try:
                golden = load_trace(base)
            except Exception as exc:
                report = DiffReport(
                    problems=[f"{name}: {type(exc).__name__}: {exc}"]
                )
            else:
                if not golden.config.get("replayable", True):
                    report = DiffReport(
                        problems=[f"{name}: manifest config is not replayable"]
                    )
                else:
                    try:
                        fresh = record_trace(golden.config)
                    except Exception as exc:
                        report = DiffReport(problems=[
                            f"{name}: re-record failed: "
                            f"{type(exc).__name__}: {exc}"
                        ])
                    else:
                        report = diff_traces(golden, fresh)
        results[name] = report.to_json()
        status = "ok" if report.identical else "DRIFT"
        print(f"[trace verify] {name:40s} {status}")
        if not report.identical:
            print(report.render())
            failed += 1
    if args.json:
        from ..telemetry import provenance

        payload = {
            "identical": failed == 0,
            "provenance": provenance(),
            "traces": results,
            "golden_dir": args.dir,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# report written to {args.json}", file=sys.stderr)
    print(
        f"# verify: {len(manifests) - failed}/{len(manifests)} traces conform",
        file=sys.stderr,
    )
    return 1 if failed else 0


# ---------------------------------------------------------------------- #
def make_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.trace", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = parser.add_subparsers(dest="command", required=True)

    rec = sub.add_parser("record", help="run one configuration and save a trace")
    rec.add_argument("--out", required=True, help="output path (base or .npz)")
    for key, default in CONFIG_DEFAULTS.items():
        if key == "fanouts":
            rec.add_argument(
                "--fanouts",
                type=lambda s: [int(x) for x in s.split(",")],
                default=None, help="e.g. 10,25",
            )
        else:
            rec.add_argument(
                f"--{key.replace('_', '-')}", dest=key,
                type=_parse_bool if isinstance(default, bool) else type(default),
                default=None, help=f"default {default!r}",
            )
    rec.set_defaults(func=cmd_record)

    rep = sub.add_parser(
        "replay", help="rebuild from the manifest config, replay, diff"
    )
    rep.add_argument("trace", help="trace path (base, .npz or .json)")
    rep.add_argument(
        "--plane", choices=("full", "decision", "time"), default="full",
        help="what to re-run against the recorded upstream streams",
    )
    rep.add_argument(
        "--runtime", choices=("vectorized", "legacy"), default=None,
        help="override the recorded runtime (full replay)",
    )
    rep.add_argument("--json", default=None, help="write the JSON report here")
    rep.set_defaults(func=cmd_replay)

    dif = sub.add_parser("diff", help="first-divergence report of two traces")
    dif.add_argument("a")
    dif.add_argument("b")
    dif.add_argument("--json", default=None, help="write the JSON report here")
    dif.set_defaults(func=cmd_diff)

    ver = sub.add_parser(
        "verify", help="re-record every trace under DIR and diff (CI gate)"
    )
    ver.add_argument("dir", help="directory of golden traces")
    ver.add_argument("--json", default=None, help="write the JSON report here")
    ver.set_defaults(func=cmd_verify)
    return parser


def main(argv=None) -> int:
    args = make_parser().parse_args(argv)
    try:
        return args.func(args)
    except (OSError, ValueError, json.JSONDecodeError) as exc:
        # Missing artifacts, digest/schema mismatches, corrupt manifests:
        # operator errors, not crashes — report and exit like a CLI.
        print(f"error: {exc}", file=sys.stderr)
        return 2
