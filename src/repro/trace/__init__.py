"""Trace plane — deterministic capture/replay of the exact run streams.

The fifth plane of the reproduction (see ``docs/ARCHITECTURE.md``
§"Trace plane"): every parity contract in the repo — legacy vs
vectorized runtime, closed-form vs event time engine, kernel vs numpy
scoring — is a statement that two executions produce *the same streams*.
This package makes "the streams" a first-class, versioned artifact:

* :class:`TraceRecorder` (:mod:`.capture`) — hooked into both runtimes
  behind ``DistributedTrainer(trace=...)``, records the canonical
  per-minibatch record (seeds, remote frontiers, miss sets split by home
  partition, decisions with validity/stall accounting, replacement
  admissions, byte counts, per-PE step times, event timeline);
* :class:`Trace` / schema (:mod:`.schema`) — dtype-normalized arrays
  (ids always int64) + JSON manifest with config, array specs and a
  payload digest, so a trace recorded on one platform replays
  bit-identically on another;
* :func:`save_trace` / :func:`load_trace` (:mod:`.store`) — compressed
  npz payload + committed-reviewable JSON manifest, digest-verified;
* :func:`diff_traces` (:mod:`.diff`) — structured first-divergence
  report (field, step, PE, values), the artifact the golden-trace CI
  gate uploads;
* replay adapters (:mod:`.replay`) — feed a recorded upstream stream
  into one plane (decision plane, time engine) so plane changes are
  testable without re-running everything upstream;
* ``python -m repro.trace`` (:mod:`.cli`) — ``record`` / ``replay`` /
  ``diff`` / ``verify`` subcommands.

Golden traces for all four controller variants x async/sync live under
``tests/golden/`` (regenerate with ``tests/golden/regenerate.py``); the
conformance suite is ``tests/test_trace_golden.py`` and the workflow is
documented in ``docs/TESTING.md``.
"""

from .capture import TraceRecorder, controller_validity
from .diff import DiffReport, Divergence, diff_traces, write_report_json
from .replay import (
    metrics_at,
    replay_decisions,
    replay_decisions_report,
    replay_time_engine,
    replay_time_engine_report,
)
from .schema import ID_DTYPE, SCHEMA_VERSION, Trace, normalize_ids
from .store import load_trace, save_trace, trace_paths

__all__ = [
    "SCHEMA_VERSION",
    "ID_DTYPE",
    "Trace",
    "normalize_ids",
    "TraceRecorder",
    "controller_validity",
    "save_trace",
    "load_trace",
    "trace_paths",
    "diff_traces",
    "DiffReport",
    "Divergence",
    "write_report_json",
    "metrics_at",
    "replay_decisions",
    "replay_decisions_report",
    "replay_time_engine",
    "replay_time_engine_report",
]
