"""Cluster simulation plane — wall-clock models for the exact streams.

The three batched planes (sample → decide → fetch) produce *exact*
per-minibatch artifacts: hit/miss sets, fetched-node counts split by
home partition, decision streams, replacement rounds. This package
prices those streams in time, two interchangeable ways behind one
:class:`TimeEngine` interface (``DistributedTrainer(time_engine=...)``):

* ``"closed_form"`` — the paper's §4.5.3 formulas (the default);
* ``"event"`` — a deterministic discrete-event simulator with
  per-trainer/per-link timelines, max–min fair home-egress contention,
  straggler/jitter injection, a wall-clock agent-daemon lane, and
  prefetcher-thread replacement overlap.

With no dynamic conditions injected the event engine reproduces the
closed form **bit-identically** (the parity contract,
``tests/test_runtime_parity.py``); see ``docs/ARCHITECTURE.md``
§"Simulation plane".
"""

from __future__ import annotations

import numpy as np

from ..graph.generate import (
    CONGESTION_PRESETS,
    STRAGGLER_PRESETS,
    CongestionModel,
    StragglerModel,
    make_congestion,
    make_stragglers,
)
from .contention import Flow, simulate_flows
from .engine import (
    ClosedFormTimeEngine,
    EventTimeEngine,
    SimConfig,
    StepComm,
    TimeEngine,
    build_step_comm,
)
from .events import EventLog, SimEvent

#: Valid ``DistributedTrainer(time_engine=...)`` / ``--time-engine`` values.
TIME_ENGINES = ("closed_form", "event")


def make_time_engine(
    kind: str,
    *,
    tm,
    mode: str,
    inference_cost,
    feature_dim: int,
    num_pes: int,
    topology=None,
    stragglers: StragglerModel | None = None,
    congestion: CongestionModel | None = None,
    config: SimConfig | None = None,
    total_steps: int = 0,
) -> TimeEngine:
    """Build a fresh per-run time engine.

    The closed form cannot express dynamic conditions, so passing a
    straggler/congestion model (or a non-default :class:`SimConfig`)
    with ``kind="closed_form"`` is an error rather than a silent no-op.
    """
    inference_cost = np.asarray(inference_cost, dtype=np.float64)
    if kind == "closed_form":
        if stragglers is not None or congestion is not None or (
            config is not None and config != SimConfig(
                collect_events=config.collect_events
            )
        ):
            raise ValueError(
                "stragglers/congestion/SimConfig knobs require "
                "time_engine='event' (the closed form cannot express them)"
            )
        return ClosedFormTimeEngine(
            tm, mode, inference_cost, feature_dim, num_pes, topology
        )
    if kind == "event":
        return EventTimeEngine(
            tm, mode, inference_cost, feature_dim, num_pes,
            topology=topology, stragglers=stragglers, congestion=congestion,
            config=config, total_steps=total_steps,
        )
    raise ValueError(
        f"time_engine must be one of {TIME_ENGINES}, got {kind!r}"
    )


__all__ = [
    "TIME_ENGINES",
    "TimeEngine",
    "ClosedFormTimeEngine",
    "EventTimeEngine",
    "SimConfig",
    "StepComm",
    "build_step_comm",
    "make_time_engine",
    "EventLog",
    "SimEvent",
    "Flow",
    "simulate_flows",
    "StragglerModel",
    "STRAGGLER_PRESETS",
    "make_stragglers",
    "CongestionModel",
    "CONGESTION_PRESETS",
    "make_congestion",
]
