"""Time engines: closed-form §4.5.3 formulas vs discrete-event cluster sim.

Both runtimes (the legacy per-trainer loop and the vectorized
three-stage pipeline) delegate *all* wall-clock modeling to one
:class:`TimeEngine` per run. Per minibatch the runtime hands the engine
the **exact** communication artifacts it produced — per-PE missed-fetch
and replacement-admission counts, split by home partition when the
engine asks for it (``needs_pairs``) — plus the controller stall ticks;
the engine returns the per-PE step times the §4.5.3 accounting logs. The byte/hit/decision streams are never touched:
time engines only *price* them.

* :class:`ClosedFormTimeEngine` — the paper's closed-form model
  (``async = max(T_DDP, T_COMM)``, ``sync = T_DDP + T_COMM + T_A/C``),
  flat constants or per-pair :class:`repro.graph.generate.Topology`
  pricing. One shared helper, :meth:`repro.gnn.train.TimeModel.
  step_time_batch`, holds the async/sync arithmetic.

* :class:`EventTimeEngine` — the simulation plane. Each minibatch step
  is scheduled on per-trainer and per-link timelines starting at the
  gradient all-reduce barrier: compute intervals (per-PE straggler
  multipliers + seeded jitter), fetch RPCs as fluid flows with max–min
  fair egress sharing (:mod:`repro.sim.contention`), the agent daemon
  as a real interval that async mode hides only while compute+comm
  cover it, and optional prefetcher-thread replacement overlap.

**Parity contract** (``tests/test_runtime_parity.py``): with no
stragglers, no congestion, default :class:`SimConfig` and a flat (or
``None``) topology, the event engine's per-step times are **bit
identical** to the closed-form engine for all four variants in both
modes — the event decomposition degenerates to single uncontended flows
whose finish times are computed by the *same* arithmetic, and the step
composition calls the *same* ``TimeModel.step_time_batch`` helper.
Divergence appears exactly when a dynamic condition is injected:
stragglers stretch compute and skew the barrier, congestion shares home
egress links, ``SimConfig.t_agent`` prices the inference daemon in
wall-clock, ``SimConfig.replacement_overlap`` lets the prefetcher's
ReplaceandFetch RPC run concurrently with the miss fetch.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..graph.generate import CongestionModel, StragglerModel, Topology
from .contention import Flow, simulate_flows
from .events import EventLog, SimEvent


@dataclass
class StepComm:
    """One minibatch's exact communication artifacts, all PEs.

    ``miss[p]`` / ``repl[p]`` are PE p's missed-fetch and
    replacement-admission node counts; the ``*_pairs`` matrices split
    them by home partition (``pairs[p, q]`` = nodes trainer p pulls from
    partition q) and are built only when the engine's ``needs_pairs``
    asks for them.
    """

    miss: np.ndarray                      # (P,) int64
    repl: np.ndarray                      # (P,) int64
    miss_pairs: np.ndarray | None = None  # (P, P) int64
    repl_pairs: np.ndarray | None = None  # (P, P) int64


def build_step_comm(
    missed: list[np.ndarray],
    placed: list[np.ndarray],
    part_of: np.ndarray | None,
    num_parts: int,
    needs_pairs: bool,
    id_base: int = 0,
) -> StepComm:
    """Assemble one step's :class:`StepComm` from per-PE node-id lists.

    ``missed[p]`` / ``placed[p]`` are the exact node ids PE p fetched on
    miss / admitted into its buffer this round. The per-home split is
    one flattened bincount per stream, keyed ``trainer_row * P + home``.
    Node ids are global (``id_base`` + local index); ``part_of`` is
    local-indexed, so ids are rebased before the home lookup.
    """
    P = num_parts
    miss = np.array([len(m) for m in missed], dtype=np.int64)
    repl = np.array([len(x) for x in placed], dtype=np.int64)
    if not needs_pairs:
        return StepComm(miss, repl)
    if part_of is None:
        raise ValueError("per-home pricing needs part_of")

    def pairs_of(node_lists: list[np.ndarray]) -> np.ndarray:
        lengths = [len(x) for x in node_lists]
        rows = np.repeat(np.arange(P, dtype=np.int64), lengths)
        nodes = (
            np.concatenate(node_lists)
            if sum(lengths)
            else np.array([], dtype=np.int64)
        )
        return np.bincount(
            rows * P + part_of[nodes - id_base], minlength=P * P
        ).reshape(P, P)

    return StepComm(miss, repl, pairs_of(missed), pairs_of(placed))


@dataclass(frozen=True)
class SimConfig:
    """Event-engine knobs beyond the scenario models.

    Defaults are the **parity configuration**: inference priced exactly
    as the closed form does (hidden in async, ``stalls * t_ddp`` in
    sync) and replacement traffic aggregated into the miss RPC. Setting
    ``t_agent`` prices the daemon thread in wall-clock seconds per
    latency tick — async then hides it only while compute+comm actually
    cover it, and the sync stall is charged at ``t_agent`` per tick.
    ``replacement_overlap`` issues ReplaceandFetch as its own concurrent
    RPC (Algorithm 1's prefetcher thread) instead of serializing its
    bytes into the miss fetch.
    """

    t_agent: float | None = None
    replacement_overlap: bool = False
    collect_events: bool = True


def _closed_form_t_comm(tm, topology, comm: StepComm, feature_dim: int):
    """The §4.5.3 T_COMM pricing — the single source both the closed-form
    engine and the event engine's parity path call, so the two cannot
    drift (drift would silently break the bit-identical parity contract).
    """
    if topology is None:
        return tm.t_comm_batch(comm.miss + comm.repl, feature_dim)
    return topology.t_comm_pairs(
        comm.miss_pairs + comm.repl_pairs, feature_dim, tm.feature_bytes
    )


class TimeEngine:
    """Per-run wall-clock model; see module docstring."""

    kind: str = "base"
    #: Whether :meth:`step` needs the per-home ``*_pairs`` matrices.
    needs_pairs: bool = False
    #: Event timeline (:class:`repro.sim.events.EventLog`) or None.
    events: EventLog | None = None

    def step(self, comm: StepComm, stalls: np.ndarray) -> np.ndarray:
        """Price one minibatch; returns (P,) step times in seconds."""
        raise NotImplementedError


class ClosedFormTimeEngine(TimeEngine):
    """The paper's §4.5.3 closed-form model (flat or per-pair priced)."""

    kind = "closed_form"

    def __init__(
        self,
        tm,
        mode: str,
        inference_cost: np.ndarray,
        feature_dim: int,
        num_pes: int,
        topology: Topology | None = None,
    ):
        self.tm = tm
        self.mode = mode
        self.inference_cost = np.asarray(inference_cost, dtype=np.float64)
        self.feature_dim = int(feature_dim)
        self.num_pes = int(num_pes)
        self.topology = topology
        self.needs_pairs = topology is not None

    def step(self, comm, stalls):
        t_comm = _closed_form_t_comm(
            self.tm, self.topology, comm, self.feature_dim
        )
        return self.tm.step_time_batch(
            t_comm, np.asarray(stalls, dtype=np.float64),
            self.inference_cost, self.mode,
        )


class EventTimeEngine(TimeEngine):
    """Discrete-event cluster simulation (see module docstring).

    Every step starts at the previous gradient all-reduce barrier, so
    event times are step-relative; the engine keeps the absolute cluster
    clock (``clock``) for the cross-step agent-daemon lane. One engine
    instance prices one run — construct a fresh one per ``run()``.
    """

    kind = "event"

    def __init__(
        self,
        tm,
        mode: str,
        inference_cost: np.ndarray,
        feature_dim: int,
        num_pes: int,
        topology: Topology | None = None,
        stragglers: StragglerModel | None = None,
        congestion: CongestionModel | None = None,
        config: SimConfig | None = None,
        total_steps: int = 0,
    ):
        self.tm = tm
        self.mode = mode
        self.inference_cost = np.asarray(inference_cost, dtype=np.float64)
        self.feature_dim = int(feature_dim)
        self.num_pes = P = int(num_pes)
        self.topology = topology
        self.stragglers = stragglers
        self.congestion = congestion
        self.config = config or SimConfig()
        self.total_steps = int(total_steps)
        if stragglers is not None and stragglers.num_parts != P:
            raise ValueError(
                f"straggler model is {stragglers.num_parts}-way, cluster is {P}"
            )
        if congestion is not None and congestion.num_parts != P:
            raise ValueError(
                f"congestion model is {congestion.num_parts}-way, cluster is {P}"
            )
        # The flow decomposition issues per-peer RPCs in parallel; a
        # serialized fetch loop (reduce='sum') has no static flow starts.
        if (
            topology is not None
            and topology.reduce != "max"
            and (congestion is not None or self.config.replacement_overlap
                 or self.config.t_agent is not None)
        ):
            raise ValueError(
                "event-engine flow decomposition requires a reduce='max' "
                f"topology, got reduce={topology.reduce!r}"
            )
        self.needs_pairs = topology is not None or congestion is not None
        self.events = EventLog() if self.config.collect_events else None
        self._rng = np.random.default_rng(
            stragglers.seed if stragglers is not None else 0
        )
        self._step_idx = 0
        self.clock = 0.0
        # Async agent-daemon twin (mirrors InferencePipe tick accounting,
        # priced in wall-clock on the `agent` lane).
        self._agent_busy = np.zeros(P, dtype=bool)
        self._agent_ready_tick = np.zeros(P, dtype=np.float64)
        self._agent_free_at = np.zeros(P, dtype=np.float64)  # cluster time

    # ------------------------------------------------------------------ #
    def _compute_durations(self) -> np.ndarray:
        """Per-PE compute interval lengths (stragglers + seeded jitter)."""
        if self.stragglers is None:
            return np.full(self.num_pes, self.tm.t_ddp, dtype=np.float64)
        mult = np.asarray(self.stragglers.compute_mult, dtype=np.float64)
        if self.stragglers.jitter > 0:
            mult = mult * np.exp(
                self.stragglers.jitter
                * self._rng.standard_normal(self.num_pes)
            )
        return self.tm.t_ddp * mult

    def _agent_tick_async(self) -> np.ndarray:
        """Advance the daemon lane one tick; returns per-PE shift.

        The shift is how long the prefetcher must wait, past the step
        barrier, for the in-flight inference to finish in wall-clock —
        zero whenever the covered steps were long enough to hide it (and
        always zero in the parity configuration, where inference is
        priced at ``t_ddp`` per tick and every step lasts >= t_ddp).
        """
        P = self.num_pes
        shift = np.zeros(P, dtype=np.float64)
        t_agent = (
            self.config.t_agent if self.config.t_agent is not None
            else self.tm.t_ddp
        )
        now = self._step_idx
        for p in range(P):
            latency = self.inference_cost[p]
            if latency <= 0:
                continue
            if self._agent_busy[p] and now >= self._agent_ready_tick[p]:
                lag = max(0.0, self._agent_free_at[p] - self.clock)
                if self.config.t_agent is not None:
                    shift[p] = lag
                self._agent_busy[p] = False
            if not self._agent_busy[p]:
                self._agent_busy[p] = True
                self._agent_ready_tick[p] = now + max(latency, 1e-9)
                self._agent_free_at[p] = (
                    self.clock + shift[p] + latency * t_agent
                )
                if self.events is not None:
                    self.events.add(SimEvent(
                        step=now, lane="agent", kind="infer", pe=p,
                        t0=float(shift[p]),
                        t1=float(shift[p] + latency * t_agent),
                    ))
        return shift

    # ------------------------------------------------------------------ #
    def step(self, comm, stalls):
        tm = self.tm
        P = self.num_pes
        fd = self.feature_dim
        stalls = np.asarray(stalls, dtype=np.float64)
        d_compute = self._compute_durations()
        shift = (
            self._agent_tick_async()
            if self.mode == "async"
            else np.zeros(P, dtype=np.float64)
        )
        t_stall = self.config.t_agent  # None -> helper charges t_ddp

        split = (
            self.congestion is not None
            or self.config.replacement_overlap
            or (self.config.t_agent is not None and self.mode == "async")
        )
        if not split:
            # Parity path: identical arithmetic to the closed form —
            # one aggregated uncontended RPC per PE (or per-pair
            # topology pricing), composed by the shared helpers.
            t_comm = _closed_form_t_comm(tm, self.topology, comm, fd)
            step_times = tm.step_time_batch(
                t_comm, stalls, self.inference_cost, self.mode,
                t_ddp=d_compute, t_stall=t_stall,
            )
            if self.events is not None:
                serial = (self.mode == "sync") & (self.inference_cost > 0)
                nbytes = (comm.miss + comm.repl) * fd * tm.feature_bytes
                for p in range(P):
                    start = float(d_compute[p]) if serial[p] else 0.0
                    if t_comm[p] > 0:
                        self.events.add(SimEvent(
                            step=self._step_idx, lane="net", kind="fetch",
                            pe=p, t0=start, t1=start + float(t_comm[p]),
                            nbytes=int(nbytes[p]),
                        ))
        else:
            step_times = self._step_flows(
                comm, stalls, d_compute, shift, t_stall
            )

        if self.events is not None:
            for p in range(P):
                self.events.add(SimEvent(
                    step=self._step_idx, lane="compute", kind="ddp", pe=p,
                    t0=0.0, t1=float(d_compute[p]),
                ))
            barrier = float(step_times.max()) if P else 0.0
            self.events.add(SimEvent(
                step=self._step_idx, lane="cluster", kind="barrier", pe=-1,
                t0=barrier, t1=barrier,
            ))
        self.clock += float(step_times.max()) if P else 0.0
        self._step_idx += 1
        return step_times

    # ------------------------------------------------------------------ #
    def _step_flows(
        self, comm, stalls, d_compute, shift, t_stall
    ) -> np.ndarray:
        """Full event decomposition: per-link fluid flows + lane merge."""
        tm = self.tm
        P = self.num_pes
        fd = self.feature_dim
        fb = tm.feature_bytes
        serial = (self.mode == "sync") & (self.inference_cost > 0)
        miss_start = np.where(serial, d_compute, 0.0)
        # Replacement RPCs wait for the daemon's wall-clock completion
        # (async agent lag) and, without overlap, ride the miss RPC.
        overlap = self.config.replacement_overlap

        # One RPC descriptor per (PE, link): per home partition when the
        # engine prices per-pair / shares egress, else the flat model's
        # single aggregated RPC on the PE's own ingress link (home=-1).
        def rpcs(p: int):
            if not self.needs_pairs:
                yield -1, int(comm.miss[p]), int(comm.repl[p]), tm.alpha, tm.link_bw
                return
            for q in range(P):
                if q == p:
                    continue
                alpha, bw = (
                    (float(self.topology.alpha[p, q]),
                     float(self.topology.bw[p, q]))
                    if self.topology is not None
                    else (tm.alpha, tm.link_bw)
                )
                yield q, int(comm.miss_pairs[p, q]), int(comm.repl_pairs[p, q]), alpha, bw

        flows: list[Flow] = []
        for p in range(P):
            for home, m, r, alpha, bw in rpcs(p):
                if not overlap and shift[p] == 0.0:
                    m, r = m + r, 0
                if m > 0:
                    flows.append(Flow(
                        pe=p, home=home, nbytes=float(m * fd * fb),
                        alpha=alpha, bw=bw, start=float(miss_start[p]),
                    ))
                if r > 0:
                    flows.append(Flow(
                        pe=p, home=home, nbytes=float(r * fd * fb),
                        alpha=alpha, bw=bw,
                        start=float(miss_start[p] + shift[p]),
                        kind="replace",
                    ))
        egress = (
            self.congestion.egress_at(self._step_idx, self.total_steps)
            if self.congestion is not None
            else None
        )
        finish = simulate_flows(flows, egress)
        comm_end = np.zeros(P, dtype=np.float64)
        for flow, end in zip(flows, finish):
            comm_end[flow.pe] = max(comm_end[flow.pe], float(end))
            if self.events is not None:
                self.events.add(SimEvent(
                    step=self._step_idx, lane="net", kind=flow.kind,
                    pe=flow.pe, t0=flow.start, t1=float(end),
                    src=flow.home, nbytes=int(flow.nbytes),
                ))
        base = np.maximum(d_compute, comm_end)
        t_per_tick = t_stall if t_stall is not None else tm.t_ddp
        return base + np.where(serial, stalls * t_per_tick, 0.0)
