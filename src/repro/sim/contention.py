"""Flow-level discrete-event contention model for fetch RPCs.

The closed-form §4.5.3 model prices every trainer's fetch traffic
independently — as if each home partition had infinite egress. Real
clusters serialize: when several trainers pull features from the same
home partition concurrently, they share that partition's egress link.

This module simulates one minibatch's fetch RPCs as *fluid flows* on an
event timeline (the standard flow-level network model): each flow has a
start offset, a per-RPC latency ``alpha``, a byte size, and a per-flow
rate cap (the pair's bandwidth from :class:`repro.graph.generate.
Topology`, or the flat ``TimeModel.link_bw``). Flows pulling from the
same home partition share its egress capacity **max–min fairly**; rates
are recomputed at every event (flow arrival or completion), and the
simulation advances from event to event — a deterministic progressive
filling with no randomness and no time discretization.

With no egress capacities (``egress_bw=None``) every flow runs at its
own cap and the finish time degenerates to the closed-form
``start + alpha + nbytes / bw`` — the arithmetic the parity contract
relies on (``tests/test_sim.py::TestFlowSim``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Flow:
    """One aggregated fetch RPC: trainer ``pe`` pulling from ``home``.

    ``home == -1`` marks a flat-model flow on the trainer's own ingress
    link — never subject to egress sharing.
    """

    pe: int
    home: int
    nbytes: float
    alpha: float
    bw: float
    start: float = 0.0
    kind: str = "fetch"

    def __post_init__(self):
        if self.nbytes <= 0:
            raise ValueError("flows must carry bytes (skip empty fetches)")
        if self.bw <= 0:
            raise ValueError("flow rate cap must be > 0")


def _waterfill(caps: np.ndarray, capacity: float) -> np.ndarray:
    """Max–min fair rates for flows with per-flow ``caps`` sharing one
    link of ``capacity``. Ascending-cap order: a flow capped below its
    fair share frees the residual for the rest."""
    n = len(caps)
    rates = np.empty(n, dtype=np.float64)
    remaining = float(capacity)
    left = n
    for i in np.argsort(caps, kind="stable"):
        rate = min(float(caps[i]), remaining / left)
        rates[i] = rate
        remaining -= rate
        left -= 1
    return rates


def _rates(
    flows: list[Flow], active: list[int], egress_bw: np.ndarray | None
) -> dict[int, float]:
    """Current rate of every active flow under max–min egress sharing."""
    by_home: dict[int, list[int]] = {}
    for i in active:
        by_home.setdefault(flows[i].home, []).append(i)
    rates: dict[int, float] = {}
    for home, members in by_home.items():
        caps = np.array([flows[i].bw for i in members], dtype=np.float64)
        if home < 0 or egress_bw is None or egress_bw[home] >= caps.sum():
            fair = caps  # uncontended: every flow at its own cap
        else:
            fair = _waterfill(caps, float(egress_bw[home]))
        for i, rate in zip(members, fair):
            rates[i] = float(rate)
    return rates


def simulate_flows(
    flows: list[Flow], egress_bw: np.ndarray | None = None
) -> np.ndarray:
    """Run the fluid simulation; returns each flow's finish time.

    ``egress_bw[q]`` is home partition q's egress capacity in bytes/s
    (``None`` disables sharing entirely). Finish times are absolute on
    the same clock as ``Flow.start``. Completions fire at their exactly
    projected instants (no residual-byte thresholds), so the simulation
    is deterministic and never stalls on rounding.
    """
    n = len(flows)
    finish = np.zeros(n, dtype=np.float64)
    if n == 0:
        return finish
    # Transfer begins after the per-RPC latency.
    arrival = np.array([f.start + f.alpha for f in flows], dtype=np.float64)
    order = np.argsort(arrival, kind="stable")
    remaining = np.array([f.nbytes for f in flows], dtype=np.float64)
    shared = np.zeros(n, dtype=bool)  # ever ran below its own cap
    active: list[int] = []
    next_arrival = 0  # index into `order`
    t = float(arrival[order[0]])
    while active or next_arrival < n:
        # Admit every flow that has arrived by now.
        while next_arrival < n and arrival[order[next_arrival]] <= t:
            active.append(int(order[next_arrival]))
            next_arrival += 1
        if not active:
            t = float(arrival[order[next_arrival]])
            continue
        rates = _rates(flows, active, egress_bw)
        for i in active:
            if rates[i] < flows[i].bw:
                shared[i] = True
        projected = {i: t + remaining[i] / rates[i] for i in active}
        t_fin = min(projected.values())
        t_arr = (
            float(arrival[order[next_arrival]]) if next_arrival < n else np.inf
        )
        if t_arr < t_fin:
            # An arrival changes the rate allocation before anything
            # completes: advance the fluid state and re-solve.
            for i in active:
                remaining[i] -= rates[i] * (t_arr - t)
            t = t_arr
            continue
        # One or more completions fire at t_fin (ties complete together).
        tol = 1e-12 * max(abs(t_fin), 1.0)
        done = [i for i in active if projected[i] <= t_fin + tol]
        for i in active:
            if i not in done:
                remaining[i] -= rates[i] * (t_fin - t)
        for i in done:
            # A flow that was never shared ran at its cap start-to-end:
            # report the closed-form finish (exact arithmetic, which the
            # parity contract depends on) instead of the fluid-advance
            # rounding of the same value.
            finish[i] = (
                flows[i].start + (flows[i].alpha + flows[i].nbytes / flows[i].bw)
                if not shared[i]
                else projected[i]
            )
            remaining[i] = 0.0
            active.remove(i)
        t = t_fin
    return finish
