"""Event taxonomy and log for the cluster simulation plane.

One :class:`SimEvent` is an interval on a named lane of the simulated
cluster. Lanes and kinds (see ``docs/ARCHITECTURE.md`` §"Simulation
plane"):

========  =========  ====================================================
lane      kind       meaning
========  =========  ====================================================
compute   ddp        trainer ``pe``'s forward+backward+allreduce compute
net       fetch      one aggregated feature-fetch RPC: trainer ``pe``
                     pulling ``nbytes`` from home partition ``src``
                     (``src == -1`` for the flat single-link model)
net       replace    the prefetcher's ReplaceandFetch RPC for nodes
                     admitted into the persistent buffer
agent     infer      the daemon inference thread busy on a decision
                     request (submit → complete)
cluster   barrier    the gradient all-reduce barrier closing the step
                     (``pe == -1``; ``t1`` is the step's cluster time)
========  =========  ====================================================

Times are *step-relative* seconds (every step starts at 0 at the
barrier); ``step`` is the global minibatch index. The log is a plain
append-only list of frozen tuples so two runs can be compared with
``==`` — the determinism contract of ``tests/test_sim.py``.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SimEvent:
    """One interval on a simulated lane (step-relative seconds)."""

    step: int
    lane: str      # compute | net | agent | cluster
    kind: str      # ddp | fetch | replace | infer | barrier
    pe: int        # trainer PE (-1 for cluster-wide events)
    t0: float
    t1: float
    src: int = -1  # home partition served (net lane), else -1
    nbytes: int = 0

    def __post_init__(self):
        if self.t1 < self.t0:
            raise ValueError(f"event ends before it starts: {self}")


class EventLog:
    """Append-only, order-preserving record of one simulated run."""

    def __init__(self):
        self._events: list[SimEvent] = []

    def add(self, event: SimEvent) -> None:
        self._events.append(event)

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self):
        return iter(self._events)

    def __getitem__(self, i):
        return self._events[i]

    def as_tuples(self) -> list[tuple]:
        """Comparable/serializable rendering (determinism checks)."""
        return [
            (e.step, e.lane, e.kind, e.pe, e.t0, e.t1, e.src, e.nbytes)
            for e in self._events
        ]

    def lane(self, lane: str) -> list[SimEvent]:
        return [e for e in self._events if e.lane == lane]

    def summary(self) -> dict:
        """Per-kind counts and busy seconds (quick inspection helper)."""
        out: dict[str, dict] = {}
        for e in self._events:
            slot = out.setdefault(e.kind, {"count": 0, "busy_s": 0.0})
            slot["count"] += 1
            slot["busy_s"] += e.t1 - e.t0
        return out
