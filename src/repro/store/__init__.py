"""Sharded feature-store data plane (the sixth plane).

Until this package existed, remote feature fetches were *accounting
entries*: the exact planes produced miss sets and byte counts, the
simulation plane priced them, but no feature row ever moved. The
:class:`FeatureStore` turns the simulator into a system — partitioned
feature shards held as device arrays (partition-major layout, optionally
sharded across this process's jax devices via the
:mod:`repro.models.sharding` mesh machinery, with a host-local numpy
fallback) service the batched miss sets coming out of
:class:`repro.runtime.stage.FetchStage` with **real gathers**
(:func:`repro.kernels.ops.gather_rows_batch` on the kernel path), and
buffer admissions place **real rows** into the
:class:`repro.runtime.PrefetchEngine` payload, not just ids.

The load-bearing contract (``tests/test_feature_store.py``,
``tests/test_trace_golden.py``): with the store enabled, the
hit/miss/byte/decision streams are bit-identical to the modeled path —
the store only *moves* the bytes the accounting already counted — while
the trace gains measured fields (``bytes_measured`` vs
``bytes_modeled``, wall-clock ``fetch_time_measured``, content-sensitive
``feat_sums``). See ``docs/ARCHITECTURE.md`` §"FeatureStore plane".
"""

from .feature_store import FeatureStore, StoreGather

__all__ = ["FeatureStore", "StoreGather"]
