"""Partition-major sharded feature store.

Layout: node features live in one partition-major padded table. With K
partitions of at most ``N_max`` nodes, node ``v`` homed on partition
``k`` at local rank ``r`` (rank = position within the home partition's
id-sorted node list) sits at flat row ``loc[v] = k * N_max + r`` of a
``(K * N_max, F)`` float32 table — equivalently slice ``k`` of the
stacked ``(K, N_max, F)`` shard view. A gather of any id set is then a
single vectorized row gather, whatever mix of home partitions the ids
span; the per-home routing that a DistDGL KVStore pull performs
(one RPC per home partition) is only materialized on the kernel path,
where :func:`repro.kernels.ops.gather_rows_batch` consumes exactly that
``(K, M_max)`` per-shard request matrix.

Backends:

* ``"numpy"`` — host-local fallback; the flat table is a numpy array and
  gathers are fancy indexing. This is the bit-exactness reference (rows
  are verbatim copies of ``Graph.features`` rows) and the default on a
  single-device host.
* ``"jax"`` — the flat table is a jax device array, sharded across this
  process's devices over the 1-D :data:`repro.models.sharding.DATA_AXIS`
  mesh when the row count divides (the :func:`repro.models.sharding.guard`
  rule — otherwise replicated). Gathers are ``jnp.take``; values are
  bit-identical to the numpy path (a gather copies rows, it never
  rounds).
* ``backend="auto"`` picks ``"jax"`` on a multi-device host and
  ``"numpy"`` otherwise.

``use_kernel=True`` additionally routes gathers through the Pallas
batch-gather kernel: requests are bucketed by home partition into a
dense ``(K, M_max)`` local-row matrix and served by one
``gather_rows_batch`` call (interpret mode on CPU).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from .. import telemetry as tel


@dataclass
class StoreGather:
    """Result of one batched (multi-PE) store gather."""

    blocks: list[np.ndarray]  # per-request (m_i, F) float32 feature blocks
    nbytes: int               # bytes actually moved out of the store
    seconds: float            # wall-clock time of the gather
    #: Concatenated block as a jax device array (``gather_batch(...,
    #: device=True)``) — the fused device hot path scatters it straight
    #: into the device-resident engine payload without a second
    #: host→device upload. None on host-only gathers.
    device_block: object = None


class FeatureStore:
    """Per-partition feature shards behind a single gather interface.

    Parameters
    ----------
    features:
        ``(N, F)`` feature matrix (any float dtype; stored as float32,
        matching :class:`repro.graph.generate.Graph` features).
    part_of:
        ``(N,)`` home partition per node.
    num_parts:
        Partition count ``K``; inferred from ``part_of`` when omitted.
    backend:
        ``"numpy"`` | ``"jax"`` | ``"auto"`` (see module docstring).
    use_kernel:
        Serve gathers through ``repro.kernels.ops.gather_rows_batch``
        (per-home routing into the stacked shard view).
    id_base:
        Global-id offset of the graph: gather/lookup ids are global
        (``id_base`` + local row), rebased to local before indexing
        ``loc``. The device view stays local-indexed — wide-id kernels
        rebase inside the launch with the same static ``id_base``.
    """

    def __init__(
        self,
        features: np.ndarray,
        part_of: np.ndarray,
        num_parts: int | None = None,
        backend: str = "auto",
        use_kernel: bool = False,
        id_base: int = 0,
    ):
        features = np.asarray(features, dtype=np.float32)
        if features.ndim != 2:
            raise ValueError(f"features must be (N, F), got {features.shape}")
        part_of = np.asarray(part_of, dtype=np.int64)
        if part_of.shape != (features.shape[0],):
            raise ValueError(
                f"part_of shape {part_of.shape} != ({features.shape[0]},)"
            )
        if part_of.size and part_of.min() < 0:
            raise ValueError("part_of must be non-negative")
        K = int(num_parts) if num_parts is not None else int(part_of.max(initial=0)) + 1
        if part_of.size and int(part_of.max()) >= K:
            raise ValueError("part_of references a partition >= num_parts")
        self.num_parts = K
        self.num_nodes, self.feature_dim = features.shape
        self.id_base = int(id_base)
        counts = np.bincount(part_of, minlength=K)
        self.shard_sizes = counts.astype(np.int64)
        self.n_max = int(counts.max(initial=0)) or 1

        # loc[v] = home * N_max + local_rank; ranks follow ascending node
        # id within each home partition (stable, derivable on any host).
        order = np.argsort(part_of, kind="stable")  # groups homes, keeps id order
        rank = np.empty(self.num_nodes, dtype=np.int64)
        rank[order] = np.arange(self.num_nodes, dtype=np.int64) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts
        )
        self._loc = part_of * self.n_max + rank

        flat = np.zeros((K * self.n_max, self.feature_dim), dtype=np.float32)
        flat[self._loc] = features
        self._flat = flat

        if backend == "auto":
            import jax

            backend = "jax" if len(jax.devices()) > 1 else "numpy"
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.use_kernel = bool(use_kernel)
        self._dev = None          # jax flat table (backend="jax")
        self._tables = None       # jax (K, N_max, F) shard view (kernel path)
        self._dev_view = None     # (flat table, int32 loc) for the megakernel
        if backend == "jax":
            self._dev = self._device_table()

    # ------------------------------------------------------------------ #
    @classmethod
    def for_partitions(cls, parts, **kwargs) -> "FeatureStore":
        """Build from a :class:`repro.graph.partition.Partitioned`."""
        kwargs.setdefault("id_base", int(parts.graph.id_base))
        return cls(
            parts.graph.features, parts.part_of, parts.num_parts, **kwargs
        )

    # ------------------------------------------------------------------ #
    @property
    def nbytes(self) -> int:
        return self._flat.nbytes

    @property
    def shards(self) -> np.ndarray:
        """Stacked ``(K, N_max, F)`` shard view of the flat table."""
        return self._flat.reshape(self.num_parts, self.n_max, self.feature_dim)

    def home_of(self, ids) -> np.ndarray:
        local = np.asarray(ids, dtype=np.int64) - self.id_base
        return self._loc[local] // self.n_max

    def _device_table(self):
        """Flat table as a jax array, row-sharded over the data mesh
        when the divisibility guard admits it (replicated otherwise)."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec

        from ..models.sharding import DATA_AXIS, guard

        mesh = Mesh(np.array(jax.devices()), (DATA_AXIS,))
        spec = guard(mesh, PartitionSpec(DATA_AXIS, None), self._flat.shape)
        return jax.device_put(
            jnp.asarray(self._flat), NamedSharding(mesh, spec)
        )

    def device_view(self):
        """``(table, loc)`` device pair for the single-launch hot path.

        ``table`` is the flat ``(K * N_max, F)`` float32 store as a jax
        array and ``loc`` the int32 node→row map; the fused frontier
        kernel gathers admission rows from these *inside* the launch, so
        the feature payload never crosses the host boundary. Cached
        until :meth:`poke` invalidates it. Requires the flat row count
        to be int32-addressable — the same bound the device engine
        already enforces on node ids."""
        if self._dev_view is None:
            import jax.numpy as jnp

            from ..kernels import ops

            if not ops.int32_id_eligible(self._flat.shape[0] - 1):
                raise ValueError(
                    "feature store flat table has >= 2^31 rows; "
                    "device view indexes rows as int32"
                )
            self._dev_view = (
                self._dev if self._dev is not None else jnp.asarray(self._flat),
                jnp.asarray(self._loc.astype(np.int32)),
            )
        return self._dev_view

    # ------------------------------------------------------------------ #
    def _rows_of(self, ids: np.ndarray) -> np.ndarray:
        flat = ids.reshape(-1).astype(np.int64, copy=False)
        if self.id_base:
            flat = flat - np.int64(self.id_base)
        if flat.size:
            lo, hi = int(flat.min()), int(flat.max())
            if lo < 0 or hi >= self.num_nodes:
                raise IndexError(
                    f"node id out of range "
                    f"[{self.id_base}, {self.id_base + self.num_nodes}): "
                    f"min {lo + self.id_base}, max {hi + self.id_base}"
                )
        return self._loc[flat]

    def _gather_rows(self, rows: np.ndarray) -> np.ndarray:
        if self.use_kernel:
            return self._gather_rows_kernel(rows)
        if self.backend == "jax":
            import jax.numpy as jnp

            return np.asarray(jnp.take(self._dev, jnp.asarray(rows), axis=0))
        return self._flat[rows]

    def _gather_rows_kernel(self, rows: np.ndarray) -> np.ndarray:
        """Per-home routing through the Pallas batch gather: bucket the
        request by home partition into a dense (K, M_max) local-row
        matrix — the DistDGL KVStore pull shape — and serve every shard
        in one ``gather_rows_batch`` call."""
        from ..kernels import ops

        K, F = self.num_parts, self.feature_dim
        M = rows.shape[0]
        if M == 0:
            return np.zeros((0, F), dtype=np.float32)
        home = rows // self.n_max
        local = rows - home * self.n_max
        order = np.argsort(home, kind="stable")
        counts = np.bincount(home, minlength=K)
        m_max = max(int(counts.max(initial=0)), 1)
        idx = np.zeros((K, m_max), dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        sorted_local = local[order]
        for k in range(K):
            idx[k, : counts[k]] = sorted_local[starts[k] : starts[k] + counts[k]]
        if self._tables is None:
            import jax.numpy as jnp

            self._tables = jnp.asarray(self.shards)
        out = np.asarray(ops.gather_rows_batch(self._tables, idx))
        gathered = np.concatenate([out[k, : counts[k]] for k in range(K)])
        result = np.empty((M, F), dtype=np.float32)
        result[order] = gathered
        return result

    # ------------------------------------------------------------------ #
    def gather(self, ids) -> np.ndarray:
        """Feature rows of ``ids`` — any shape, any int dtype; returns
        ``ids.shape + (F,)`` float32, bit-identical to
        ``graph.features[ids]``."""
        arr = np.asarray(ids)
        rows = self._rows_of(arr)
        block = self._gather_rows(rows)
        return block.reshape(arr.shape + (self.feature_dim,))

    def gather_batch(self, id_lists, device: bool = False) -> StoreGather:
        """One timed gather for a whole cluster's per-PE request lists.

        The P ragged requests are served by a single concatenated row
        gather and split back — this is the batched data path
        ``FetchStage.commit`` drives, and what the store microbenchmark
        races against a per-PE, per-home python pull loop.

        ``device=True`` additionally returns the concatenated block as a
        jax device array (``StoreGather.device_block``): the fused
        device hot path (:class:`repro.runtime.stage.FusedFetchStage`)
        scatters admission rows into the device-resident engine payload
        without re-uploading the block it just pulled. The numpy blocks
        (and every exact stream derived from them) are unchanged.
        """
        sp = tel.span("store.gather", plane="store")
        sp.__enter__()
        t0 = time.perf_counter()
        lengths = [len(x) for x in id_lists]
        if sum(lengths):
            ids = np.concatenate(
                [np.asarray(x, dtype=np.int64).reshape(-1) for x in id_lists]
            )
        else:
            ids = np.array([], dtype=np.int64)
        block = self._gather_rows(self._rows_of(ids))
        blocks = [
            np.ascontiguousarray(b)
            for b in np.split(block, np.cumsum(lengths)[:-1])
        ]
        device_block = None
        if device:
            import jax.numpy as jnp

            device_block = jnp.asarray(block)
        seconds = time.perf_counter() - t0
        sp.nbytes = int(block.nbytes)
        sp.__exit__(None, None, None)
        if tel.enabled():
            tel.count("store.bytes", block.nbytes)
            tel.count("store.gathers", 1)
            tel.count(
                "store.rows",
                np.asarray(lengths, dtype=np.float64),
            )
        return StoreGather(
            blocks=blocks,
            nbytes=int(block.nbytes),
            seconds=seconds,
            device_block=device_block,
        )

    # ------------------------------------------------------------------ #
    def poke(self, node_id: int, delta: float = 1.0) -> None:
        """Fault injection: corrupt one shard row in place (the golden
        drift negative test — a poked store must surface in the trace's
        ``feat_sums`` stream at the first step that fetches the node)."""
        row = self._loc[int(node_id) - self.id_base]
        self._flat[row] += np.float32(delta)
        self._tables = None
        self._dev_view = None
        if self.backend == "jax":
            self._dev = self._device_table()
