"""AdamW with configurable moment dtype.

Moments default to fp32; very large models (DeepSeek-V3) use bf16
moments (``cfg.opt_dtype``) so the optimizer state fits v5e HBM — the
trade-off is noted in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: float | jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float = 1.0,
):
    step = state.step + 1

    if grad_clip > 0:
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
        )

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int
):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        progress = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(progress, 0.0, 1.0)))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)

    return schedule
