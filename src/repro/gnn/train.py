"""Distributed GNN training driver — the paper's evaluation harness.

Runs the three variants of §5 on a partitioned graph:

* ``distdgl``      — no prefetch: every sampled remote node is fetched;
* ``fixed``        — static prefetch: replacement round every minibatch;
* ``massivegnn``   — warm-started buffer, fixed replacement interval;
* ``rudder``       — adaptive replacement via LLM agent / ML classifier
                     behind the async/sync queue protocol.

What is *exact*: partitioning, sampling, buffer membership/scoring,
hit/miss sets, remote fetch counts (bytes), decision streams, GNN
training math (JAX GraphSAGE with data-parallel gradient averaging —
Rudder never alters sampling or training, so accuracy is unaffected by
the variant, as the paper states).

What is *modeled*: wall-clock epoch time, via the paper's own §4.5.3
performance model driven by the exact byte counts:

    async step time = max(T_DDP, T_COMM)          (inference hidden)
    sync  step time = T_DDP + T_COMM + T_A/C      (inference exposed)

with T_COMM = alpha + fetched_bytes / link_bw per trainer and the step
synchronised across trainers by the gradient all-reduce (max over PEs).
Constants are documented in :class:`TimeModel`. With ``topology=...``
the flat constants are replaced by the per-pair cluster cost model of
:class:`repro.graph.generate.Topology` (fetch RPCs priced by home
partition); the exact byte counts are unchanged.

With ``time_engine="event"`` the same exact streams are priced by the
discrete-event cluster simulator of :mod:`repro.sim` instead: per-trainer
and per-link timelines with max–min fair home-egress contention
(``congestion=...``), per-PE straggler/jitter compute multipliers
(``stragglers=...``), a wall-clock agent-daemon lane and
prefetcher-thread replacement overlap (``sim=SimConfig(...)``). With no
scenario injected the event engine reproduces the closed form
bit-identically (the parity contract of ``tests/test_runtime_parity.py``).

Two interchangeable execution paths produce the run (see
``docs/ARCHITECTURE.md``):

* ``runtime="vectorized"`` (default) — the batched multi-PE
  :class:`repro.runtime.PrefetchEngine` loop, used by every benchmark
  and the ``--sweep`` grid runner;
* ``runtime="legacy"`` — the original one-PE-at-a-time Python loop,
  kept as the semantic reference; ``tests/test_runtime_parity.py``
  asserts the two are bit-identical on hits, misses, bytes and decision
  streams for all four variants.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from .. import telemetry as tel
from ..core import scoring
from ..core.buffer import PersistentBuffer
from ..core.controller import Controller, make_controller
from ..core.metrics import GraphMeta, Metrics
from ..graph.generate import (
    CongestionModel,
    Graph,
    StragglerModel,
    Topology,
    make_congestion,
    make_stragglers,
    make_topology,
)
from ..graph.partition import Partitioned
from ..graph.sampler import MiniBatch, NeighborSampler, SamplerPlane, unique_remote
from ..runtime.engine import PrefetchEngine
from .sage import init_sage, sage_accuracy, sage_grads


@dataclass
class TimeModel:
    """Calibrated constants for the §4.5.3 performance model.

    ``t_ddp`` is the data-parallel compute time of one minibatch on one
    trainer (forward+backward+allreduce). At paper scale (A100, batch
    2000, fanout {10,25}) this is ~50 ms. ``link_bw`` is the per-trainer
    effective bandwidth of the RPC fetch path: Slingshot gives ~2.5 GB/s
    effective per trainer at full scale; our graphs (and therefore the
    per-minibatch fetch sets) are scaled down ~100x, so the default
    bandwidth is scaled by the same factor (~1 MB/s, i.e. ~100 MB/s
    effective TCP RPC bandwidth at full scale) to keep
    T_COMM / T_DDP in the paper's regime (baseline communication roughly
    comparable to compute, §5.1). ``alpha`` is the per-round RPC latency.
    """

    t_ddp: float = 0.050
    link_bw: float = 1e6
    alpha: float = 5e-4
    feature_bytes: int = 4

    def t_comm(self, fetched_nodes: int, feature_dim: int) -> float:
        if fetched_nodes == 0:
            return 0.0
        return self.alpha + fetched_nodes * feature_dim * self.feature_bytes / self.link_bw

    def t_comm_batch(self, fetched_nodes: np.ndarray, feature_dim: int) -> np.ndarray:
        """Vectorized :meth:`t_comm` over all trainer PEs at once (the
        single source of the formula for the vectorized runtime)."""
        fetched_nodes = np.asarray(fetched_nodes)
        return np.where(
            fetched_nodes > 0,
            self.alpha
            + fetched_nodes * feature_dim * self.feature_bytes / self.link_bw,
            0.0,
        )

    def step_time_batch(
        self,
        t_comm: np.ndarray,
        stalls: np.ndarray,
        inference_cost: np.ndarray,
        mode: str,
        t_ddp: np.ndarray | float | None = None,
        t_stall: float | None = None,
    ) -> np.ndarray:
        """The §4.5.3 async/sync step-time composition, all PEs at once.

        This is the **single** statement of the paper's formulas —
        ``async = max(T_DDP, T_COMM)`` (inference hidden) and
        ``sync = T_DDP + T_COMM + stalls * T_A/C`` for PEs whose
        controller pays inference (non-adaptive PEs overlap comm with
        compute in either mode). The legacy loop, the vectorized
        :class:`repro.runtime.stage.FetchStage` and the event engine's
        parity path all price steps through here, so the three cannot
        drift. ``t_ddp`` admits per-PE compute durations (the event
        engine's straggler axis) and ``t_stall`` re-prices one stall
        tick (its wall-clock agent axis); both default to the closed
        form's flat ``t_ddp`` constant.
        """
        t_ddp = self.t_ddp if t_ddp is None else t_ddp
        t_stall = self.t_ddp if t_stall is None else t_stall
        if mode == "sync":
            return np.where(
                np.asarray(inference_cost) > 0,
                t_ddp + t_comm + np.asarray(stalls) * t_stall,
                np.maximum(t_ddp, t_comm),
            )
        return np.maximum(t_ddp, t_comm)


@dataclass
class TrainerLog:
    pct_hits: list[float] = field(default_factory=list)
    comm_volume: list[int] = field(default_factory=list)
    comm_missed: list[int] = field(default_factory=list)
    occupancy: list[float] = field(default_factory=list)
    unique_remote: list[int] = field(default_factory=list)
    replaced: list[int] = field(default_factory=list)
    decisions: list[bool] = field(default_factory=list)
    step_time: list[float] = field(default_factory=list)
    # Feature-store streams (populated only when the store is enabled):
    # bytes the store actually moved vs the §4.5.3 accounting bytes, the
    # measured wall-clock of the step's gathers, and the
    # content-sensitive float64 sum of the delivered remote block.
    bytes_measured: list[int] = field(default_factory=list)
    bytes_modeled: list[int] = field(default_factory=list)
    fetch_seconds: list[float] = field(default_factory=list)
    feat_sums: list[float] = field(default_factory=list)


@dataclass
class RunResult:
    variant: str
    epoch_times: list[float]
    losses: list[float]
    accuracy: float
    logs: list[TrainerLog]
    controllers: list[Controller]
    graph_meta: list[GraphMeta]
    #: Event timeline of the run (``repro.sim.EventLog``) when priced by
    #: the event engine; None under the closed-form model.
    sim_events: object | None = None
    #: Recorded run trace (``repro.trace.Trace``) when the trainer was
    #: built with ``trace=...``; None otherwise.
    trace: object | None = None
    #: Flat telemetry summary (``TelemetrySession.summary()``) when the
    #: trainer was built with ``telemetry=...``; None otherwise.
    telemetry: dict | None = None

    # ---- aggregates used across the benchmark suite ------------------- #
    # Aggregates over an *empty* run (zero epochs / zero logged
    # minibatches) are NaN, not 0.0: a silent zero looks like a perfect
    # run in sweep artifacts, while NaN trips the CI gate
    # (``runtime.sweep.validate_rows``).
    @property
    def mean_epoch_time(self) -> float:
        return float(np.mean(self.epoch_times)) if self.epoch_times else float("nan")

    @property
    def mean_pct_hits(self) -> float:
        vals = [h for log in self.logs for h in log.pct_hits]
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def total_comm(self) -> int:
        return int(sum(sum(log.comm_volume) for log in self.logs))

    @property
    def comm_per_minibatch(self) -> float:
        n = sum(len(log.comm_volume) for log in self.logs)
        return self.total_comm / n if n else float("nan")

    @property
    def steady_pct_hits(self) -> float:
        """Mean %-Hits over the last quarter of the run (post cold-start)."""
        vals = []
        for log in self.logs:
            n = len(log.pct_hits)
            vals.extend(log.pct_hits[max(n - n // 4, 1):])
        return float(np.mean(vals)) if vals else float("nan")

    def comm_p99(self) -> float:
        vals = [c for log in self.logs for c in log.comm_volume]
        return float(np.percentile(vals, 99)) if vals else float("nan")

    # ---- feature-store aggregates (0 / NaN when the store was off) ---- #
    @property
    def total_bytes_measured(self) -> int:
        return int(sum(sum(log.bytes_measured) for log in self.logs))

    @property
    def total_bytes_modeled(self) -> int:
        return int(sum(sum(log.bytes_modeled) for log in self.logs))

    @property
    def total_fetch_seconds(self) -> float:
        """Measured wall-clock spent in store gathers (cluster steps sum
        the per-step maximum across PEs, like epoch_times does)."""
        per_step = zip(*(log.fetch_seconds for log in self.logs))
        vals = [max(step) for step in per_step]
        return float(sum(vals)) if vals else float("nan")


class DistributedTrainer:
    """One experiment: (graph, partitioning, variant, controller, buffer)."""

    def __init__(
        self,
        parts: Partitioned,
        variant: str = "rudder",
        deciders: list | None = None,
        buffer_frac: float = 0.25,
        batch_size: int = 256,
        fanouts: tuple[int, ...] = (10, 25),
        epochs: int = 5,
        lr: float = 1e-2,
        hidden_dim: int = 64,
        mode: str = "async",
        interval: int = 32,
        warm_start: bool = True,
        train_model: bool = True,
        time_model: TimeModel | None = None,
        seed: int = 0,
        runtime: str = "vectorized",
        policy: str | scoring.ScoringPolicy = "rudder",
        topology: str | Topology | None = None,
        time_engine: str = "closed_form",
        stragglers: str | StragglerModel | None = None,
        congestion: str | CongestionModel | None = None,
        sim=None,
        trace: object = False,
        feature_store: object = False,
        device: object = False,
        readback_every: int = 1,
        telemetry: object = False,
    ):
        if runtime not in ("vectorized", "legacy"):
            raise ValueError(
                f"runtime must be 'vectorized' or 'legacy', got {runtime!r}"
            )
        # Device-resident hot path (docs/ARCHITECTURE.md §"Device-resident
        # hot path"): False/None = staged numpy pipeline; True/"jnp" =
        # persistent jax device buffers + the fused jit'd oracle;
        # "pallas" = the fused Pallas megakernel (kernels/fused_step.py).
        # Streams stay bit-identical on every setting
        # (tests/test_fused_step.py).
        if device not in (False, None, True, "jnp", "pallas"):
            raise ValueError(
                "device must be False, True, 'jnp' or 'pallas', "
                f"got {device!r}"
            )
        if device and runtime == "legacy":
            raise ValueError("device mode requires runtime='vectorized'")
        self.device = device or False
        # K-step readback cadence for sweep runs: with device mode on and
        # K > 1, the driver pulls only a stacked (K, P, 4) counter block
        # every K launches instead of a per-step readback. Incompatible
        # with anything that consumes per-step id streams — the driver
        # raises (see repro.runtime.driver._check_cadence_eligible).
        if not isinstance(readback_every, (int, np.integer)) or isinstance(
            readback_every, bool
        ) or readback_every < 1:
            raise ValueError(
                f"readback_every must be an int >= 1, got {readback_every!r}"
            )
        if readback_every > 1 and not device:
            raise ValueError("readback_every > 1 requires device=...")
        self.readback_every = int(readback_every)
        if time_engine not in ("closed_form", "event"):
            raise ValueError(
                "time_engine must be 'closed_form' or 'event', "
                f"got {time_engine!r}"
            )
        self.parts = parts
        self.graph: Graph = parts.graph
        self.variant = variant
        self.runtime = runtime
        self.policy = scoring.make_policy(policy)
        self.buffer_frac = buffer_frac
        self.batch_size = batch_size
        self.epochs = epochs
        self.lr = lr
        self.mode = mode
        self.train_model = train_model
        self.tm = time_model or TimeModel()
        # Per-pair comm pricing (None keeps the flat §4.5.3 constants).
        if isinstance(topology, str):
            topology = make_topology(
                topology, parts.num_parts,
                link_bw=self.tm.link_bw, alpha=self.tm.alpha,
            )
        if topology is not None and topology.num_parts != parts.num_parts:
            raise ValueError(
                f"topology is {topology.num_parts}-way but the graph is "
                f"partitioned {parts.num_parts}-way"
            )
        self.topology = topology
        # Wall-clock model: closed-form §4.5.3 (default) or the event
        # simulator of repro.sim. Scenario presets resolve here so the
        # sweep can pass plain strings; a fresh engine is built per run
        # (make_time_engine) so event logs never leak across runs.
        if isinstance(stragglers, str):
            stragglers = (
                None
                if stragglers == "none"
                else make_stragglers(stragglers, parts.num_parts, seed=seed)
            )
        if isinstance(congestion, str):
            congestion = (
                None
                if congestion == "none"
                else make_congestion(
                    congestion, parts.num_parts, link_bw=self.tm.link_bw
                )
            )
        if time_engine == "closed_form" and (
            stragglers is not None or congestion is not None
        ):
            raise ValueError(
                "stragglers/congestion scenarios require time_engine='event' "
                "(the closed-form model cannot express them)"
            )
        self.time_engine = time_engine
        self.stragglers = stragglers
        self.congestion = congestion
        self.sim = sim
        self.last_time_engine = None
        # Trace capture (repro.trace): False/None = off (zero overhead),
        # True = record with a default recorder, or a TraceRecorder
        # instance (the CLI/sweep pass one carrying the full replayable
        # config). The finished Trace lands on self.last_trace.
        self.trace = trace
        self.last_trace = None
        # Telemetry plane (repro.telemetry): False/None = off (zero
        # overhead — no session is ever constructed); True = collect
        # into a fresh TelemetrySession; a TelemetrySession instance is
        # used as-is (single-use, like recorders). The finished session
        # lands on self.last_telemetry and its summary on
        # RunResult.telemetry. Never perturbs exact streams.
        self.telemetry = telemetry
        self.last_telemetry = None
        # Feature-store data plane (repro.store): False/None = modeled
        # bytes only; True = build a store over this graph's partitioned
        # features; a FeatureStore instance is used as-is. With the
        # store on, buffers and engine carry a real feature payload and
        # both runtimes move the bytes the accounting counts — without
        # changing any exact stream (the conformance contract of
        # tests/test_trace_golden.py).
        self.feature_store = None
        if feature_store:
            from ..store import FeatureStore

            self.feature_store = (
                feature_store
                if isinstance(feature_store, FeatureStore)
                else FeatureStore.for_partitions(parts)
            )
        self.rng = np.random.default_rng(seed)
        self.sampler = NeighborSampler(self.graph, fanouts)
        # Batched twin of the per-PE sampler: all P trainers' minibatches
        # advance in one pass (bit-identical draws; see SamplerPlane).
        self.sampler_plane = SamplerPlane(self.graph, fanouts)

        P = parts.num_parts
        self.graph_meta = [
            GraphMeta(
                name=self.graph.name,
                num_nodes=self.graph.num_nodes,
                num_edges=self.graph.num_edges,
                part_nodes=len(parts.local_nodes[p]),
                part_edges=parts.part_edges(p),
                num_partitions=P,
            )
            for p in range(P)
        ]

        # Halo (total remote nodes per partition): distinct 1-hop
        # neighbors homed elsewhere — the reference set for buffer sizing
        # ("5%/25% of remote nodes relative to total remote nodes per
        # partition", §5.1).
        self.halos = []
        for p in range(P):
            nodes = parts.local_nodes[p]
            nbrs = np.unique(
                np.concatenate(
                    [self.graph.neighbors(int(u)) for u in nodes]
                    or [np.array([], dtype=np.int64)]
                )
            )
            self.halos.append(nbrs[parts.part_of[nbrs] != p])

        # The degree policy weighs accesses by the node's (log) degree.
        node_weights = (
            scoring.degree_weights(self.graph.degree())
            if self.policy.use_weights
            else None
        )
        payload_dim = (
            self.graph.features.shape[1] if self.feature_store is not None else 0
        )
        self.buffers = [
            PersistentBuffer(
                capacity=max(int(len(self.halos[p]) * buffer_frac), 1),
                feature_dim=payload_dim,
                policy=self.policy,
                node_weights=node_weights,
                id_base=self.graph.id_base,
            )
            for p in range(P)
        ]
        # Vectorized twin of the per-PE buffers: one (P, C) array state.
        self.engine = PrefetchEngine(
            [b.capacity for b in self.buffers],
            policy=self.policy,
            node_weights=node_weights,
            feature_dim=payload_dim,
            id_base=self.graph.id_base,
        )

        # Controllers (one per trainer, as in the paper: each trainer has
        # its own prefetcher + daemon inference thread).
        self.controllers: list[Controller] = []
        for p in range(P):
            decider = None
            if variant == "rudder":
                if deciders is None:
                    raise ValueError("rudder variant needs deciders")
                decider = deciders[p % len(deciders)]
            self.controllers.append(
                make_controller(
                    variant,
                    graph=self.graph_meta[p],
                    decider=decider,
                    mode=mode,
                    interval=interval,
                    warm_start=warm_start,
                )
            )

        # MassiveGNN warm start: prefetch the highest-degree remote halo
        # nodes before training (§5.1 "Comparison with MassiveGNN").
        if variant == "massivegnn" and warm_start:
            deg = self.graph.degree()
            base = np.int64(self.graph.id_base)
            for p in range(P):
                halo = self.halos[p]
                top = halo[np.argsort(-deg[halo])][: self.buffers[p].capacity]
                # Buffer/engine/store ids live in the global id space.
                top = top + base
                n = self.buffers[p].insert(top)
                self.engine.insert(p, top)
                if self.feature_store is not None and n:
                    # Warm-started admissions place real rows too (top is
                    # unique and the buffer empty, so exactly top[:n]
                    # landed, in order, in both twins).
                    rows = self.feature_store.gather(top[:n])
                    self.buffers[p].fill_rows(top[:n], rows)
                    self.engine.place_rows(p, self.engine.last_slots[p], rows)

        self.local_train = [parts.local_train_nodes(p) for p in range(P)]
        self.mb_per_epoch = max(
            1,
            max(
                (len(t) + batch_size - 1) // batch_size
                for t in self.local_train
                if len(t)
            ),
        )

        if train_model:
            key = jax.random.PRNGKey(seed)
            self.params = init_sage(
                key,
                self.graph.features.shape[1],
                hidden_dim,
                self.graph.num_classes,
            )

    # ------------------------------------------------------------------ #
    def _seed_batch(self, p: int, epoch: int, mb: int) -> np.ndarray:
        t = self.local_train[p]
        if len(t) == 0:
            return self.graph.train_nodes[: self.batch_size]
        perm = np.random.default_rng((epoch * 1000003 + p) ^ 0xC0FFEE).permutation(
            len(t)
        )
        start = (mb * self.batch_size) % len(t)
        idx = perm[start : start + self.batch_size]
        if len(idx) < min(self.batch_size, len(t)):
            idx = np.concatenate([idx, perm[: self.batch_size - len(idx)]])
        return t[idx]

    def _features_of(self, minibatch: MiniBatch):
        if self.feature_store is not None:
            # The training step consumes actual store rows (bit-identical
            # to graph.features rows — the store only re-homes them).
            # Minibatch ids are local; the store is keyed by global id.
            store = self.feature_store
            base = np.int64(self.graph.id_base)
            x_seed = store.gather(minibatch.seeds + base)
            x_n1 = store.gather(minibatch.layer_nbrs[0] + base)
            b, f1 = minibatch.layer_nbrs[0].shape
            x_n2 = store.gather(minibatch.layer_nbrs[1] + base).reshape(
                b, f1, -1, store.feature_dim
            )
            return x_seed, x_n1, x_n2
        f = self.graph.features
        x_seed = f[minibatch.seeds]
        x_n1 = f[minibatch.layer_nbrs[0]]
        b, f1 = minibatch.layer_nbrs[0].shape
        x_n2 = f[minibatch.layer_nbrs[1]].reshape(b, f1, -1, f.shape[1])
        return x_seed, x_n1, x_n2

    # ------------------------------------------------------------------ #
    def make_time_engine(self):
        """Build a fresh per-run wall-clock engine (``repro.sim``).

        Both runtimes call this at the top of a run; the returned engine
        also stays reachable as ``self.last_time_engine`` so callers can
        inspect the event timeline after ``run()``.
        """
        from .. import sim

        engine = sim.make_time_engine(
            self.time_engine,
            tm=self.tm,
            mode=self.mode,
            inference_cost=np.array(
                [c.inference_cost for c in self.controllers],
                dtype=np.float64,
            ),
            feature_dim=self.graph.features.shape[1],
            num_pes=self.parts.num_parts,
            topology=self.topology,
            stragglers=self.stragglers,
            congestion=self.congestion,
            config=self.sim,
            total_steps=self.epochs * self.mb_per_epoch,
        )
        self.last_time_engine = engine
        return engine

    # ------------------------------------------------------------------ #
    def make_trace_recorder(self):
        """Resolve the ``trace`` flag to a recorder (or None when off).

        Both runtimes call this at the top of a run. A pre-built
        :class:`repro.trace.TraceRecorder` is used as-is (single-use —
        recorders are per-run, like time engines); ``trace=True`` builds
        a fresh default recorder from the trainer's own axes.
        """
        if not self.trace:
            return None
        from ..trace import TraceRecorder

        if isinstance(self.trace, TraceRecorder):
            return self.trace
        return TraceRecorder.for_trainer(self)

    # ------------------------------------------------------------------ #
    def make_telemetry(self):
        """Resolve the ``telemetry`` flag to a session (or None when off).

        Mirrors :meth:`make_trace_recorder`: a pre-built
        :class:`repro.telemetry.TelemetrySession` is used as-is,
        ``telemetry=True`` builds a fresh default session.
        """
        if not self.telemetry:
            return None
        from ..telemetry import TelemetrySession

        if isinstance(self.telemetry, TelemetrySession):
            return self.telemetry
        return TelemetrySession(label=self.variant)

    # ------------------------------------------------------------------ #
    def run(self) -> RunResult:
        """Execute the experiment (vectorized runtime by default).

        With ``telemetry=...`` set, the run executes under an active
        :class:`repro.telemetry.TelemetrySession`; the session lands on
        ``self.last_telemetry`` and its summary on the result.
        """
        session = self.make_telemetry()
        if session is None:
            return self._run_impl()
        from .. import telemetry as tel

        with tel.active(session):
            with session.tracer.span("run", plane="runtime"):
                result = self._run_impl()
        session.meta.setdefault("variant", self.variant)
        session.meta.setdefault("mode", self.mode)
        session.meta.setdefault("num_pes", self.parts.num_parts)
        self.last_telemetry = session
        result.telemetry = session.summary()
        return result

    def _run_impl(self) -> RunResult:
        if self.runtime == "vectorized":
            from ..runtime.driver import run_vectorized

            return run_vectorized(self)
        return self.run_legacy()

    def run_legacy(self) -> RunResult:
        """Reference implementation: one PE at a time, one Python loop.

        Kept as the semantic oracle for the vectorized runtime
        (``tests/test_runtime_parity.py``); benchmarks use :meth:`run`.
        """
        from ..sim import build_step_comm

        P = self.parts.num_parts
        logs = [TrainerLog() for _ in range(P)]
        epoch_times: list[float] = []
        losses: list[float] = []
        time_engine = self.make_time_engine()
        recorder = self.make_trace_recorder()

        # Pipeline staleness: ReplaceandFetch overlaps with training, so a
        # replacement round admits the miss set of the *previous*
        # minibatch (Algorithm 1 queues the next minibatch before the
        # decision lands). Frequent replacement therefore keeps admitting
        # one-round-old tail nodes — churn the adaptive controller avoids.
        prev_missed = [np.array([], dtype=np.int64) for _ in range(P)]
        empty = np.array([], dtype=np.int64)

        for epoch in range(self.epochs):
            epoch_time = 0.0
            for mb in range(self.mb_per_epoch):
                grads_acc = None
                loss_acc = 0.0
                missed_sets: list[np.ndarray] = []
                placed_sets: list[np.ndarray] = []
                stall_ticks: list[float] = []
                # Trace-only per-PE collections (references, not copies;
                # empty work when capture is off).
                seed_sets: list[np.ndarray] = []
                remote_sets: list[np.ndarray] = []
                hit_counts: list[int] = []
                occ_pre: list[float] = []
                # Feature-store per-PE captures (hit rows must be read at
                # lookup time — replacement may overwrite their slots).
                hit_mask_sets: list[np.ndarray] = []
                hit_row_sets: list[np.ndarray] = []
                _step_sp = tel.begin("step", plane="runtime")
                for p in range(P):
                    _pe_sp = tel.begin("pe_step", pe=p, plane="runtime")
                    ctrl = self.controllers[p]
                    buf = self.buffers[p]
                    batch = self._seed_batch(p, epoch, mb)
                    minibatch = self.sampler.sample(batch, self.rng)
                    remote = unique_remote(
                        minibatch, self.parts.part_of, p,
                        id_base=self.graph.id_base,
                    )
                    n_remote = len(remote)

                    slots = None
                    if ctrl.uses_buffer and buf.capacity > 0:
                        hit_mask, slots = buf.lookup(remote)
                        missed = remote[~hit_mask]
                        hits = int(hit_mask.sum())
                        pct_hits = (
                            100.0 * hits / n_remote if n_remote else 100.0
                        )
                    else:
                        hit_mask = np.zeros(n_remote, dtype=bool)
                        missed = remote
                        hits = 0
                        pct_hits = 0.0
                    if self.feature_store is not None:
                        hit_mask_sets.append(hit_mask)
                        hit_row_sets.append(
                            buf.features[slots[hit_mask]]
                            if slots is not None
                            else np.zeros(
                                (0, self.feature_store.feature_dim),
                                dtype=np.float32,
                            )
                        )
                    if recorder is not None:
                        seed_sets.append(batch)
                        remote_sets.append(remote)
                        hit_counts.append(hits)
                        occ_pre.append(buf.occupancy)

                    comm = len(missed)
                    metrics = Metrics(
                        minibatch=mb,
                        total_minibatches=self.mb_per_epoch,
                        epoch=epoch,
                        total_epochs=self.epochs,
                        pct_hits=pct_hits,
                        comm_volume=comm,
                        replaced_pct=(
                            100.0 * logs[p].replaced[-1] / buf.capacity
                            if logs[p].replaced and buf.capacity
                            else 0.0
                        ),
                        buffer_occupancy=buf.occupancy,
                        buffer_capacity=buf.capacity,
                    )
                    replace = ctrl.should_replace(metrics)
                    if ctrl.uses_buffer:
                        buf.end_round()
                    replaced = 0
                    if replace and ctrl.uses_buffer:
                        replaced = buf.replace(prev_missed[p])
                    prev_missed[p] = missed
                    # Replacement traffic: ReplaceandFetch (Alg. 1 line 14)
                    # issues a separate aggregated RPC for the nodes pulled
                    # into the persistent buffer — counted as communication
                    # (this is why over-replacement blows up comm, Fig. 20).
                    comm += replaced

                    logs[p].pct_hits.append(pct_hits)
                    logs[p].comm_volume.append(comm)
                    logs[p].comm_missed.append(len(missed))
                    logs[p].occupancy.append(buf.occupancy)
                    logs[p].unique_remote.append(n_remote)
                    logs[p].replaced.append(replaced)
                    logs[p].decisions.append(bool(replace))

                    # Exact per-PE communication artifacts for the time
                    # engine (priced after the PE loop, whole cluster at
                    # once — link contention couples the PEs).
                    missed_sets.append(missed)
                    placed_sets.append(
                        buf.last_placed
                        if replace and ctrl.uses_buffer
                        else empty
                    )
                    stall_ticks.append(ctrl.step_stall())

                    if self.train_model:
                        x_seed, x_n1, x_n2 = self._features_of(minibatch)
                        loss, grads = sage_grads(
                            self.params, x_seed, x_n1, x_n2, minibatch.labels
                        )
                        loss_acc += float(loss) / P
                        grads_acc = (
                            grads
                            if grads_acc is None
                            else jax.tree_util.tree_map(
                                lambda a, b: a + b, grads_acc, grads
                            )
                        )
                    tel.end(_pe_sp)

                # Wall-clock pricing of the exact streams (§4.5.3 closed
                # form or the event simulator), then the gradient sync
                # across trainers (bulk-synchronous step barrier).
                step_times = time_engine.step(
                    build_step_comm(
                        missed_sets,
                        placed_sets,
                        self.parts.part_of,
                        P,
                        time_engine.needs_pairs,
                        id_base=self.graph.id_base,
                    ),
                    np.asarray(stall_ticks, dtype=np.float64),
                )
                for p in range(P):
                    logs[p].step_time.append(float(step_times[p]))
                epoch_time += float(step_times.max())

                # Feature-store data plane: serve the exact miss/placement
                # streams with real gathers (mirrors FetchStage.commit's
                # _serve_features — two batched gathers after the PE loop,
                # hit rows already captured at lookup time above).
                store_kwargs: dict = {}
                if self.feature_store is not None:
                    store = self.feature_store
                    F = store.feature_dim
                    miss_g = store.gather_batch(missed_sets)
                    placed_g = store.gather_batch(placed_sets)
                    fetch_seconds = miss_g.seconds + placed_g.seconds
                    feat_sums = np.zeros(P, dtype=np.float64)
                    bytes_measured = np.zeros(P, dtype=np.int64)
                    bytes_modeled = np.zeros(P, dtype=np.int64)
                    for p in range(P):
                        if len(placed_sets[p]):
                            self.buffers[p].fill_rows(
                                placed_sets[p], placed_g.blocks[p]
                            )
                        block = np.empty(
                            (len(hit_mask_sets[p]), F), dtype=np.float32
                        )
                        block[hit_mask_sets[p]] = hit_row_sets[p]
                        block[~hit_mask_sets[p]] = miss_g.blocks[p]
                        feat_sums[p] = block.sum(dtype=np.float64)
                        bytes_measured[p] = (
                            miss_g.blocks[p].nbytes + placed_g.blocks[p].nbytes
                        )
                        bytes_modeled[p] = (
                            logs[p].comm_volume[-1] * F * self.tm.feature_bytes
                        )
                        logs[p].bytes_measured.append(int(bytes_measured[p]))
                        logs[p].bytes_modeled.append(int(bytes_modeled[p]))
                        logs[p].fetch_seconds.append(float(fetch_seconds))
                        logs[p].feat_sums.append(float(feat_sums[p]))
                    store_kwargs = dict(
                        feat_sums=feat_sums,
                        bytes_measured=bytes_measured,
                        bytes_modeled=bytes_modeled,
                        fetch_time_measured=np.full(
                            P, fetch_seconds, dtype=np.float64
                        ),
                    )
                if recorder is not None:
                    recorder.record_step(
                        seeds=seed_sets,
                        remote=remote_sets,
                        missed=missed_sets,
                        placed=placed_sets,
                        decisions=[logs[p].decisions[-1] for p in range(P)],
                        stalls=np.asarray(stall_ticks, dtype=np.float64),
                        pct_hits=[logs[p].pct_hits[-1] for p in range(P)],
                        hits=hit_counts,
                        n_remote=[logs[p].unique_remote[-1] for p in range(P)],
                        replaced=[logs[p].replaced[-1] for p in range(P)],
                        total_comm=[logs[p].comm_volume[-1] for p in range(P)],
                        occupancy_pre=occ_pre,
                        occupancy_post=[logs[p].occupancy[-1] for p in range(P)],
                        step_times=step_times,
                        controllers=self.controllers,
                        **store_kwargs,
                    )
                if self.train_model and grads_acc is not None:
                    grads_mean = jax.tree_util.tree_map(
                        lambda g: g / P, grads_acc
                    )
                    self.params = jax.tree_util.tree_map(
                        lambda prm, g: prm - self.lr * g, self.params, grads_mean
                    )
                    losses.append(loss_acc)
                tel.end(_step_sp)
            epoch_times.append(epoch_time)

        accuracy = 0.0
        if self.train_model:
            batch = self.graph.train_nodes[: min(512, len(self.graph.train_nodes))]
            minibatch = self.sampler.sample(batch, self.rng)
            x_seed, x_n1, x_n2 = self._features_of(minibatch)
            accuracy = float(
                sage_accuracy(self.params, x_seed, x_n1, x_n2, minibatch.labels)
            )

        trace = None
        if recorder is not None:
            trace = recorder.finalize(epoch_times, time_engine.events)
            self.last_trace = trace

        return RunResult(
            variant=self.variant,
            epoch_times=epoch_times,
            losses=losses,
            accuracy=accuracy,
            logs=logs,
            controllers=self.controllers,
            graph_meta=self.graph_meta,
            sim_events=time_engine.events,
            trace=trace,
        )


def collect_traces(
    parts: Partitioned,
    buffer_frac: float = 0.25,
    batch_size: int = 256,
    epochs: int = 3,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Trace-only mode (§4.4): run DistDGL+fixed with training disabled,
    record per-minibatch features and S'-labels for offline classifier
    training. Returns (X, y)."""
    from ..core.classifiers import featurize, label_traces

    trainer = DistributedTrainer(
        parts,
        variant="fixed",
        buffer_frac=buffer_frac,
        batch_size=batch_size,
        epochs=epochs,
        train_model=False,
        seed=seed,
    )
    result = trainer.run()
    X_rows, y_rows = [], []
    for p, log in enumerate(result.logs):
        hits = np.array(log.pct_hits)
        comm = np.array(log.comm_volume, dtype=np.float64)
        repl = np.array(log.replaced, dtype=np.float64)
        labels = label_traces(hits, comm, repl)
        cap = trainer.buffers[p].capacity
        prev = None
        recent: list[float] = []
        recent_c: list[int] = []
        for i in range(len(hits)):
            m = Metrics(
                minibatch=i % trainer.mb_per_epoch,
                total_minibatches=trainer.mb_per_epoch,
                epoch=i // trainer.mb_per_epoch,
                total_epochs=epochs,
                pct_hits=float(hits[i]),
                comm_volume=int(comm[i]),
                replaced_pct=100.0 * repl[i] / cap if cap else 0.0,
                buffer_occupancy=float(log.occupancy[i]),
                buffer_capacity=cap,
            )
            recent.append(float(hits[i]))
            recent_c.append(int(comm[i]))
            X_rows.append(featurize(m, prev, recent[-16:], recent_c[-16:]))
            y_rows.append(labels[i])
            prev = m
    return np.stack(X_rows), np.array(y_rows, dtype=np.float32)
