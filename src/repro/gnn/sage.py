"""GraphSAGE (mean aggregator) in pure JAX.

2-layer model over sampled neighborhood trees, exactly the paper's
training workload (node classification, fanout {10, 25}, batch 2000 at
full scale). The forward consumes the dense padded blocks produced by
:class:`repro.graph.sampler.NeighborSampler`:

    x_seed : (B, F)          seed features
    x_n1   : (B, f1, F)      sampled neighbors of seeds
    x_n2   : (B, f1, f2, F)  sampled neighbors of those neighbors

Aggregation is a mean over the fanout axis — the same segment-mean that
``kernels/segment_sum`` implements as a Pallas TPU kernel for the
CSR-ordered (variable-degree) full-graph case.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SageLayer(NamedTuple):
    w_self: jax.Array
    w_nbr: jax.Array
    bias: jax.Array


class SageParams(NamedTuple):
    layer1: SageLayer
    layer2: SageLayer


def init_sage(
    key: jax.Array, feature_dim: int, hidden_dim: int, num_classes: int
) -> SageParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def glorot(k, a, b):
        return jax.random.normal(k, (a, b), dtype=jnp.float32) * (
            2.0 / (a + b)
        ) ** 0.5

    return SageParams(
        layer1=SageLayer(
            w_self=glorot(k1, feature_dim, hidden_dim),
            w_nbr=glorot(k2, feature_dim, hidden_dim),
            bias=jnp.zeros((hidden_dim,), jnp.float32),
        ),
        layer2=SageLayer(
            w_self=glorot(k3, hidden_dim, num_classes),
            w_nbr=glorot(k4, hidden_dim, num_classes),
            bias=jnp.zeros((num_classes,), jnp.float32),
        ),
    )


def _sage_combine(layer: SageLayer, x_self: jax.Array, x_nbr_mean: jax.Array):
    return x_self @ layer.w_self + x_nbr_mean @ layer.w_nbr + layer.bias


def sage_forward(
    params: SageParams,
    x_seed: jax.Array,
    x_n1: jax.Array,
    x_n2: jax.Array,
) -> jax.Array:
    """Returns logits (B, num_classes)."""
    # Layer 1 applied to every node that layer 2 will read.
    h_n1 = jax.nn.relu(
        _sage_combine(params.layer1, x_n1, jnp.mean(x_n2, axis=2))
    )  # (B, f1, H)
    h_seed = jax.nn.relu(
        _sage_combine(params.layer1, x_seed, jnp.mean(x_n1, axis=1))
    )  # (B, H)
    # Layer 2 on seeds.
    logits = _sage_combine(params.layer2, h_seed, jnp.mean(h_n1, axis=1))
    return logits


def sage_loss(
    params: SageParams,
    x_seed: jax.Array,
    x_n1: jax.Array,
    x_n2: jax.Array,
    labels: jax.Array,
) -> jax.Array:
    logits = sage_forward(params, x_seed, x_n1, x_n2)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


@jax.jit
def sage_train_step(
    params: SageParams,
    x_seed: jax.Array,
    x_n1: jax.Array,
    x_n2: jax.Array,
    labels: jax.Array,
    lr: float = 1e-2,
):
    """Single-trainer SGD step; the distributed driver averages grads
    across trainers before applying (data-parallel semantics)."""
    loss, grads = jax.value_and_grad(sage_loss)(params, x_seed, x_n1, x_n2, labels)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss


@jax.jit
def sage_grads(params, x_seed, x_n1, x_n2, labels):
    return jax.value_and_grad(sage_loss)(params, x_seed, x_n1, x_n2, labels)


@jax.jit
def sage_accuracy(params, x_seed, x_n1, x_n2, labels):
    logits = sage_forward(params, x_seed, x_n1, x_n2)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
