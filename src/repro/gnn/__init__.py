"""GNN training substrate (GraphSAGE + distributed trainer)."""

from .sage import SageParams, init_sage, sage_forward, sage_loss
from .train import DistributedTrainer, RunResult, TimeModel

__all__ = [
    "SageParams",
    "init_sage",
    "sage_forward",
    "sage_loss",
    "DistributedTrainer",
    "RunResult",
    "TimeModel",
]
