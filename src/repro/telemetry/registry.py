"""Metrics registry: counters, gauges, histograms with dotted names.

The registry is the numeric half of the telemetry plane (spans are the
temporal half, :mod:`repro.telemetry.spans`). Metrics are dense numpy
accumulators so per-PE instrumentation costs one vectorized add, not a
Python loop: a counter's shape is fixed by its first ``add`` — scalar
``()`` or per-PE ``(P,)`` or per-pair ``(P, P)`` — and every later add
must match (a shape change is an instrumentation bug, so it raises).

Names are hierarchical, dot-separated: the first segment identifies the
plane/subsystem (``fetch.bytes_by_home``, ``device.fallback_int64``,
``kernel.gather_rows.calls``) and is what the CLI breakdown groups by.
"""

from __future__ import annotations

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _coerce(value) -> np.ndarray:
    return np.asarray(value, dtype=np.float64)


class Counter:
    """Monotonic accumulator; shape fixed by the first ``add``."""

    kind = "counter"

    def __init__(self, name: str, shape: tuple[int, ...] | None = None):
        self.name = name
        self._values: np.ndarray | None = (
            np.zeros(shape, dtype=np.float64) if shape is not None else None
        )

    def add(self, value=1) -> None:
        arr = _coerce(value)
        if self._values is None:
            self._values = np.zeros(arr.shape, dtype=np.float64)
        elif arr.shape != self._values.shape:
            raise ValueError(
                f"counter {self.name!r} has shape {self._values.shape}, "
                f"got add of shape {arr.shape}"
            )
        self._values += arr

    @property
    def values(self) -> np.ndarray:
        if self._values is None:
            return np.zeros((), dtype=np.float64)
        return self._values

    @property
    def total(self) -> float:
        return float(self.values.sum())

    def summary(self) -> dict:
        out: dict = {"total": self.total}
        if self.values.ndim:
            out["values"] = self.values.tolist()
        return out


class Gauge:
    """Last-write-wins value (scalar or array)."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._value: np.ndarray = np.zeros((), dtype=np.float64)

    def set(self, value) -> None:
        self._value = _coerce(value)

    @property
    def values(self) -> np.ndarray:
        return self._value

    @property
    def total(self) -> float:
        return float(self._value.sum())

    def summary(self) -> dict:
        out: dict = {"value": self.total}
        if self._value.ndim:
            out["values"] = self._value.tolist()
        return out


class Histogram:
    """Streaming distribution: count/sum/min/max plus a bounded sample.

    Observations beyond ``cap`` keep updating the moments but stop
    growing the sample, so memory stays bounded on long runs while
    percentiles remain available from the (deterministic) prefix.
    """

    kind = "histogram"

    def __init__(self, name: str, cap: int = 65536):
        self.name = name
        self.cap = cap
        self.count = 0
        self.sum = 0.0
        self.min = np.inf
        self.max = -np.inf
        self._sample: list[float] = []

    def observe(self, value) -> None:
        arr = np.atleast_1d(_coerce(value))
        if not arr.size:
            return
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        self.min = min(self.min, float(arr.min()))
        self.max = max(self.max, float(arr.max()))
        room = self.cap - len(self._sample)
        if room > 0:
            self._sample.extend(arr.ravel()[:room].tolist())

    def percentile(self, q: float) -> float:
        if not self._sample:
            return float("nan")
        return float(np.percentile(np.asarray(self._sample), q))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else float("nan"),
            "max": self.max if self.count else float("nan"),
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create store of named metrics.

    Re-requesting a name returns the existing metric; requesting it as
    a different kind raises (one name, one meaning).
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls, **kwargs):
        metric = self._metrics.get(name)
        if metric is None:
            metric = cls(name, **kwargs)
            self._metrics[name] = metric
        elif not isinstance(metric, cls):
            raise ValueError(
                f"metric {name!r} is a {metric.kind}, requested {cls.kind}"
            )
        return metric

    def counter(self, name: str, shape: tuple[int, ...] | None = None) -> Counter:
        metric = self._get(name, Counter)
        if shape is not None and metric._values is None:
            metric._values = np.zeros(shape, dtype=np.float64)
        return metric

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __len__(self) -> int:
        return len(self._metrics)

    def summary(self) -> dict:
        """Nested ``{kind: {name: summary}}`` dict, JSON-serializable."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in self.names():
            metric = self._metrics[name]
            out[metric.kind + "s"][name] = metric.summary()
        return out
