"""``python -m repro.telemetry`` — inspect run artifacts.

Subcommands:

* ``summary ARTIFACT.jsonl`` — per-plane time/bytes breakdown table
  from a run artifact written by ``TelemetrySession.write_jsonl``.
* ``chrome ARTIFACT.jsonl --out trace.json`` — convert the artifact to
  Chrome-trace/Perfetto ``trace_events`` JSON (load it at
  https://ui.perfetto.dev or chrome://tracing).
* ``calibrate TRACE`` — fit TimeModel alpha/link_bw from a recorded
  store-enabled trace's measured byte + wall-clock streams.

All error paths print to stderr and return exit code 2.
"""

from __future__ import annotations

import argparse
import json
import sys

from .calibrate import calibrate_from_trace
from .export import breakdown_rows, load_jsonl, render_table, write_chrome_trace

__all__ = ["main", "make_parser"]


def cmd_summary(args) -> int:
    artifact = load_jsonl(args.artifact)
    meta = artifact["meta"]
    if meta:
        label = meta.get("label", "?")
        sha = meta.get("provenance", {}).get("git_sha", "?")
        print(f"# run: {label}  (git {sha[:12]})")
    rows = breakdown_rows(artifact)
    if not rows:
        print("no spans or byte counters recorded")
        return 0
    print(render_table(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"rows": rows}, fh, indent=2)
        print(f"wrote {args.json}")
    return 0


def cmd_chrome(args) -> int:
    artifact = load_jsonl(args.artifact)
    path = write_chrome_trace(artifact, args.out)
    n = len(artifact["spans"])
    print(f"wrote {path} ({n} spans) — load at https://ui.perfetto.dev")
    return 0


def cmd_calibrate(args) -> int:
    from ..trace.store import load_trace

    trace = load_trace(args.trace)
    cal = calibrate_from_trace(trace)
    print(
        f"alpha={cal.alpha:.6g} s  link_bw={cal.link_bw:.6g} B/s  "
        f"(n={cal.n_samples}, max_abs_err={cal.max_abs_err_s:.3g} s)"
    )
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(cal.summary(), fh, indent=2)
        print(f"wrote {args.json}")
    return 0


def make_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Inspect telemetry run artifacts.",
    )
    sub = parser.add_subparsers(dest="command", required=True)

    p = sub.add_parser("summary", help="per-plane time/bytes breakdown")
    p.add_argument("artifact", help="JSONL artifact from write_jsonl()")
    p.add_argument("--json", default=None, help="also write rows as JSON")
    p.set_defaults(func=cmd_summary)

    p = sub.add_parser("chrome", help="export Chrome-trace/Perfetto JSON")
    p.add_argument("artifact", help="JSONL artifact from write_jsonl()")
    p.add_argument("--out", default="trace.json", help="output path")
    p.set_defaults(func=cmd_chrome)

    p = sub.add_parser(
        "calibrate", help="fit TimeModel alpha/link_bw from a trace"
    )
    p.add_argument("trace", help="trace base path (store-enabled recording)")
    p.add_argument("--json", default=None, help="write fit as JSON")
    p.set_defaults(func=cmd_calibrate)
    return parser


def main(argv=None) -> int:
    args = make_parser().parse_args(argv)
    try:
        return args.func(args)
    except (OSError, ValueError, json.JSONDecodeError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
