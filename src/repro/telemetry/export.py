"""Telemetry exporters: per-run JSONL, Chrome-trace JSON, text tables.

JSONL is the run artifact (one ``meta`` line, then one line per span
and per metric) — ``python -m repro.telemetry summary/chrome`` consume
it. The Chrome-trace exporter emits the ``trace_events`` JSON the
Perfetto UI (https://ui.perfetto.dev) and ``chrome://tracing`` load:
spans become complete events (``ph: "X"``, microsecond ``ts``/``dur``)
on one thread track per PE, with ``tid 0`` the host/driver track.
"""

from __future__ import annotations

import json
from pathlib import Path

from .provenance import provenance

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "jsonl_rows",
    "write_jsonl",
    "load_jsonl",
    "breakdown_rows",
    "render_table",
]

JSONL_SCHEMA = 1


def _track_of(pe: int) -> int:
    # Host/driver spans record pe=-1; map onto tid 0 and shift PEs up.
    return pe + 1


def _span_rows(source) -> list[dict]:
    """Accept a live session or a loaded-artifact dict."""
    if hasattr(source, "tracer"):
        return [sp.as_row() for sp in source.tracer.spans]
    return list(source.get("spans", []))


def chrome_trace(source, label: str = "repro") -> dict:
    """Build the ``trace_events`` document from a session or artifact."""
    spans = _span_rows(source)
    pes = sorted({int(sp["pe"]) for sp in spans})
    events: list[dict] = [
        {
            "ph": "M",
            "name": "process_name",
            "pid": 0,
            "tid": 0,
            "args": {"name": label},
        }
    ]
    for pe in pes:
        events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 0,
                "tid": _track_of(pe),
                "args": {"name": "host" if pe < 0 else f"PE {pe}"},
            }
        )
    for sp in spans:
        events.append(
            {
                "name": sp["name"],
                "cat": sp["plane"],
                "ph": "X",
                "ts": sp["t0"] * 1e6,
                "dur": (sp["t1"] - sp["t0"]) * 1e6,
                "pid": 0,
                "tid": _track_of(int(sp["pe"])),
                "args": {"depth": sp["depth"], "nbytes": sp.get("nbytes", 0)},
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(source, path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(source)))
    return path


# ---------------------------------------------------------------------- #
def jsonl_rows(session) -> list[dict]:
    rows: list[dict] = [
        {
            "kind": "meta",
            "jsonl_schema": JSONL_SCHEMA,
            "label": session.label,
            "provenance": provenance(),
            "meta": dict(session.meta),
        }
    ]
    for sp in session.tracer.spans:
        rows.append({"kind": "span", **sp.as_row()})
    reg = session.registry
    for name in reg.names():
        metric = reg[name]
        rows.append({"kind": metric.kind, "name": name, **metric.summary()})
    return rows


def write_jsonl(session, path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for row in jsonl_rows(session):
            fh.write(json.dumps(row) + "\n")
    return path


def load_jsonl(path) -> dict:
    """Parse a run artifact back into ``{meta, spans, metrics}``."""
    path = Path(path)
    meta: dict = {}
    spans: list[dict] = []
    metrics: list[dict] = []
    with path.open() as fh:
        for line_no, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as exc:
                raise ValueError(
                    f"{path}:{line_no}: not a telemetry JSONL artifact ({exc})"
                ) from exc
            kind = row.get("kind")
            if kind == "meta":
                meta = row
            elif kind == "span":
                spans.append(row)
            elif kind in ("counter", "gauge", "histogram"):
                metrics.append(row)
    if not meta and not spans and not metrics:
        raise ValueError(f"{path}: no telemetry rows found")
    return {"meta": meta, "spans": spans, "metrics": metrics}


# ---------------------------------------------------------------------- #
def breakdown_rows(artifact: dict) -> list[dict]:
    """Per-plane time/bytes breakdown from a loaded artifact.

    Time is *exclusive* span seconds grouped by plane; bytes come from
    counters whose name contains ``bytes`` grouped by their first
    name segment (the plane convention).
    """
    plane_s: dict[str, float] = {}
    plane_spans: dict[str, int] = {}
    for sp in artifact["spans"]:
        plane = sp["plane"]
        # exclusive time: subtract direct children, recomputed from rows
        plane_s.setdefault(plane, 0.0)
        plane_spans[plane] = plane_spans.get(plane, 0) + 1
    # Recompute child time per span from nesting (same track, enclosing
    # interval, depth+1) so loaded artifacts don't need child_s stored.
    by_track: dict[int, list[dict]] = {}
    for sp in artifact["spans"]:
        by_track.setdefault(int(sp["pe"]), []).append(sp)
    for track_spans in by_track.values():
        track_spans.sort(key=lambda s: (s["t0"], -s["t1"]))
        for sp in track_spans:
            child = sum(
                c["t1"] - c["t0"]
                for c in track_spans
                if c is not sp
                and c["depth"] == sp["depth"] + 1
                and c["t0"] >= sp["t0"]
                and c["t1"] <= sp["t1"]
            )
            plane_s[sp["plane"]] += max((sp["t1"] - sp["t0"]) - child, 0.0)

    plane_bytes: dict[str, float] = {}
    for metric in artifact["metrics"]:
        if metric["kind"] == "counter" and "bytes" in metric["name"]:
            plane = metric["name"].split(".", 1)[0]
            plane_bytes[plane] = plane_bytes.get(plane, 0.0) + metric["total"]

    planes = sorted(set(plane_s) | set(plane_bytes))
    return [
        {
            "plane": plane,
            "spans": plane_spans.get(plane, 0),
            "self_s": plane_s.get(plane, 0.0),
            "bytes": plane_bytes.get(plane, 0.0),
        }
        for plane in planes
    ]


def render_table(rows: list[dict]) -> str:
    """Fixed-width per-plane breakdown table."""
    header = f"{'plane':<12} {'spans':>8} {'self_s':>12} {'bytes':>14}"
    lines = [header, "-" * len(header)]
    for row in rows:
        lines.append(
            f"{row['plane']:<12} {row['spans']:>8d} "
            f"{row['self_s']:>12.6f} {row['bytes']:>14.0f}"
        )
    total_s = sum(r["self_s"] for r in rows)
    total_b = sum(r["bytes"] for r in rows)
    total_n = sum(r["spans"] for r in rows)
    lines.append("-" * len(header))
    lines.append(f"{'total':<12} {total_n:>8d} {total_s:>12.6f} {total_b:>14.0f}")
    return "\n".join(lines)
