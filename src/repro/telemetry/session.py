"""TelemetrySession: one run's registry + tracer + kernel profiler.

A session owns a :class:`MetricsRegistry` and a :class:`SpanTracer`
and is installed as the process-wide active session for the duration
of one ``DistributedTrainer.run()`` (see :func:`repro.telemetry.active`).
Instrumentation sites never hold a session reference — they ask the
module-level helpers, which are no-ops when nothing is active. That
indirection is the zero-overhead-off contract: with no session, every
hook is one global load and a ``None`` check.

Kernel profiling (``profile_call``) wraps a dispatcher call with
``jax.block_until_ready`` timing — the block is what makes the number
mean "kernel finished", not "dispatch returned" — and optionally a
``jax.profiler.TraceAnnotation`` so the span also shows up in a real
XLA profiler trace when one is being captured.
"""

from __future__ import annotations

import time
from contextlib import nullcontext

from .registry import MetricsRegistry
from .spans import SpanTracer

__all__ = ["TelemetrySession"]


class TelemetrySession:
    def __init__(
        self,
        label: str = "run",
        profile_kernels: bool = True,
        annotate: bool = False,
    ):
        self.label = label
        self.profile_kernels = profile_kernels
        self.annotate = annotate
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer()
        self.meta: dict = {}

    # -- kernel profiling ---------------------------------------------- #
    def profile_call(self, name: str, fn, *args, **kwargs):
        """Call ``fn`` with block-until-ready timing under ``name``."""
        import jax

        annotation = (
            jax.profiler.TraceAnnotation(f"repro.{name}")
            if self.annotate
            else nullcontext()
        )
        t0 = time.perf_counter()
        with annotation:
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.registry.counter(f"kernel.{name}.calls").add(1)
        self.registry.histogram(f"kernel.{name}.seconds").observe(dt)
        return out

    # -- aggregation --------------------------------------------------- #
    def summary(self) -> dict:
        """Flat JSON-safe summary merged into RunResult / sweep rows."""
        return {
            "label": self.label,
            "spans": self.tracer.summary(),
            "metrics": self.registry.summary(),
            "meta": dict(self.meta),
        }

    def brief(self) -> dict:
        """Compact per-cell summary for sweep rows: seconds by plane
        plus counter totals (no per-element arrays, no histograms)."""
        counters = {
            name: self.registry[name].total
            for name in self.registry.names()
            if self.registry[name].kind == "counter"
        }
        return {
            "wall_s": self.tracer.total_s(),
            "span_count": len(self.tracer.spans),
            "by_plane": dict(sorted(self.tracer.by_plane().items())),
            "counters": counters,
        }

    # -- export (delegates; see export.py) ----------------------------- #
    def write_jsonl(self, path) -> None:
        from .export import write_jsonl

        write_jsonl(self, path)

    def write_chrome_trace(self, path) -> None:
        from .export import write_chrome_trace

        write_chrome_trace(self, path)
