"""Fit TimeModel constants (alpha, link_bw) from measured streams.

The §4.5.3 clock prices a fetch of ``n`` bytes at
``alpha + n / link_bw`` seconds. The feature-store data plane records
what the same fetch *actually* cost (``fetch_time_measured`` +
``bytes_measured`` in store-enabled traces; ``store.gather`` spans with
``nbytes`` in telemetry sessions), so the two constants fall out of an
ordinary least-squares line through (bytes, seconds): the slope is
``1 / link_bw``, the intercept is ``alpha``. This closes the ROADMAP
item "fit TimeModel constants from the recorded fetch_time_measured
stream" — the modeled clock anchored to measured reality.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

__all__ = ["Calibration", "fit_alpha_bw", "calibrate_from_trace", "calibrate_from_session"]

# One process-wide warning when a fit degenerates (non-positive slope →
# link_bw = inf); sweeps fitting hundreds of cells should not drown in
# repeats. Reset is test-only: ``_warned_degenerate_fit = False``.
_warned_degenerate_fit = False


@dataclass
class Calibration:
    alpha: float
    link_bw: float
    n_samples: int
    max_abs_err_s: float

    def predict(self, nbytes) -> np.ndarray:
        return self.alpha + np.asarray(nbytes, dtype=np.float64) / self.link_bw

    def to_time_model(self, t_ddp: float | None = None, feature_bytes: int | None = None):
        """A TimeModel with the fitted constants (others keep defaults)."""
        from ..gnn.train import TimeModel

        kwargs = {"alpha": self.alpha, "link_bw": self.link_bw}
        if t_ddp is not None:
            kwargs["t_ddp"] = t_ddp
        if feature_bytes is not None:
            kwargs["feature_bytes"] = feature_bytes
        return TimeModel(**kwargs)

    def summary(self) -> dict:
        return {
            "alpha": self.alpha,
            "link_bw": self.link_bw,
            "n_samples": self.n_samples,
            "max_abs_err_s": self.max_abs_err_s,
        }


def fit_alpha_bw(nbytes, seconds) -> Calibration:
    """Least-squares ``seconds ~ alpha + nbytes / link_bw``.

    Zero-byte samples are dropped (the model prices an empty fetch at
    exactly 0, not alpha). Needs >= 2 samples with distinct byte counts;
    a fitted non-positive slope (measurement noise swamping the trend)
    degenerates to ``link_bw = inf`` with ``alpha = mean(seconds)``.
    """
    x = np.asarray(nbytes, dtype=np.float64).ravel()
    y = np.asarray(seconds, dtype=np.float64).ravel()
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch: {x.shape} vs {y.shape}")
    keep = np.isfinite(x) & np.isfinite(y) & (x > 0)
    x, y = x[keep], y[keep]
    if x.size < 2 or np.unique(x).size < 2:
        raise ValueError(
            "calibration needs >= 2 samples with distinct byte counts, "
            f"got {x.size} usable samples"
        )
    slope, intercept = np.polyfit(x, y, 1)
    if slope <= 0:
        global _warned_degenerate_fit
        if not _warned_degenerate_fit:
            _warned_degenerate_fit = True
            warnings.warn(
                "calibration fit has a non-positive slope (measured "
                "seconds do not grow with bytes); degenerating to "
                "link_bw=inf with alpha=mean(seconds)",
                RuntimeWarning,
                stacklevel=2,
            )
        link_bw = float("inf")
        alpha = float(y.mean())
    else:
        link_bw = 1.0 / float(slope)
        alpha = max(float(intercept), 0.0)
    pred = alpha + x / link_bw
    return Calibration(
        alpha=alpha,
        link_bw=link_bw,
        n_samples=int(x.size),
        max_abs_err_s=float(np.abs(pred - y).max()),
    )


def calibrate_from_trace(trace) -> Calibration:
    """Fit from a store-enabled :class:`repro.trace.schema.Trace`.

    Uses the per-step totals: ``bytes_measured`` summed across PEs and
    ``fetch_time_measured`` (the batched gather's wall clock, recorded
    broadcast across PEs) averaged per step.
    """
    arrays = trace.arrays
    if "bytes_measured" not in arrays or "fetch_time_measured" not in arrays:
        raise ValueError(
            "trace has no measured store streams "
            "(record with feature_store=True)"
        )
    nbytes = np.asarray(arrays["bytes_measured"]).sum(axis=1)
    seconds = np.asarray(arrays["fetch_time_measured"]).mean(axis=1)
    return fit_alpha_bw(nbytes, seconds)


def calibrate_from_session(session) -> Calibration:
    """Fit from a telemetry session's ``store.gather`` spans."""
    pairs = [
        (sp.nbytes, sp.duration)
        for sp in session.tracer.spans
        if sp.name == "store.gather" and sp.nbytes > 0
    ]
    if len(pairs) < 2:
        raise ValueError(
            "session has < 2 store.gather spans with recorded bytes"
        )
    nbytes, seconds = zip(*pairs)
    return fit_alpha_bw(np.asarray(nbytes), np.asarray(seconds))
