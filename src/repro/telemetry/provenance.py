"""Shared provenance header for bench/telemetry JSON artifacts.

Every ``BENCH_*.json`` writer and ``write_sweep_json`` stamps this
header so trajectory comparisons across PRs are attributable: which
commit, which platform, which jax. Deliberately no wall-clock
timestamp — artifacts from the same checkout must stay byte-identical
across reruns so they diff cleanly.
"""

from __future__ import annotations

import platform
import subprocess
import sys

PROVENANCE_SCHEMA = 1

__all__ = ["PROVENANCE_SCHEMA", "git_sha", "provenance"]


def git_sha() -> str:
    """HEAD sha of the enclosing checkout, or ``"unknown"``."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
            check=False,
        )
    except (OSError, subprocess.SubprocessError):
        return "unknown"
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"


def provenance() -> dict:
    """The shared artifact header: schema, git sha, platform, versions."""
    try:
        import jax

        jax_version = jax.__version__
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        jax_version = "unknown"
    import numpy as np

    return {
        "schema": PROVENANCE_SCHEMA,
        "git_sha": git_sha(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "jax": jax_version,
        "numpy": np.__version__,
    }
