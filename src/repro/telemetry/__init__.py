"""Telemetry plane: metrics registry, span tracing, kernel profiling.

The seventh plane. One :class:`TelemetrySession` (registry + tracer)
is installed process-wide for the duration of a run; instrumentation
sites across the other six planes call the module-level helpers below,
which are no-ops while no session is active.

The load-bearing contract (mirrors the trace plane's):

* **Off is free.** Telemetry defaults to off; every hook is then one
  global load + ``None`` check and *no* telemetry object is ever
  constructed — runs reproduce the committed golden traces
  bit-identically (``tests/test_telemetry.py`` pins this).
* **On never perturbs exact streams.** Spans and counters observe;
  they never feed back into sampling, scoring, decisions, or byte
  accounting — telemetry-on runs keep the same
  ``Trace.exact_digest()``. Only wall-clock (already excluded from
  exact digests) can move, within the CI-gated budget
  (``benchmarks/telemetry_smoke.py``).

Usage::

    trainer = DistributedTrainer(parts, telemetry=True)
    result = trainer.run()
    result.telemetry["spans"]["by_plane"]      # seconds per plane
    trainer.last_telemetry.write_jsonl("run.jsonl")
    # python -m repro.telemetry summary run.jsonl

See ``docs/OBSERVABILITY.md`` for the full reference.
"""

from __future__ import annotations

import functools
from contextlib import contextmanager

from .calibrate import (
    Calibration,
    calibrate_from_session,
    calibrate_from_trace,
    fit_alpha_bw,
)
from .provenance import provenance
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .session import TelemetrySession
from .spans import Span, SpanTracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "SpanTracer",
    "TelemetrySession",
    "Calibration",
    "fit_alpha_bw",
    "calibrate_from_trace",
    "calibrate_from_session",
    "provenance",
    "current",
    "enabled",
    "activate",
    "deactivate",
    "active",
    "span",
    "begin",
    "end",
    "count",
    "gauge",
    "observe",
    "spanned",
    "profiled",
]

_SESSION: TelemetrySession | None = None


class _NullSpan:
    """Shared do-nothing span for telemetry-off code paths.

    Deliberately *not* ``__slots__``-restricted: instrumented code sets
    attributes on the span it holds (``sp.nbytes = ...``) and must not
    care whether telemetry is live.
    """

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


def current() -> TelemetrySession | None:
    return _SESSION


def enabled() -> bool:
    return _SESSION is not None


def activate(session: TelemetrySession) -> TelemetrySession:
    global _SESSION
    if _SESSION is not None:
        raise RuntimeError("a telemetry session is already active")
    _SESSION = session
    return session


def deactivate() -> None:
    global _SESSION
    _SESSION = None


@contextmanager
def active(session: TelemetrySession):
    """Install ``session`` as the process-wide session for the block."""
    activate(session)
    try:
        yield session
    finally:
        deactivate()


# -- cheap instrumentation helpers (the only API call sites use) ------- #
def span(name: str, pe: int = -1, plane: str = "", nbytes: int = 0):
    s = _SESSION
    if s is None:
        return _NULL_SPAN
    return s.tracer.span(name, pe=pe, plane=plane, nbytes=nbytes)


def begin(name: str, pe: int = -1, plane: str = ""):
    """Open a span without a ``with`` block; pair with :func:`end`.

    Returns ``None`` when telemetry is off — ``end(None)`` is a no-op,
    so loop bodies stay un-indented at zero cost.
    """
    s = _SESSION
    if s is None:
        return None
    return s.tracer.begin(name, pe=pe, plane=plane)


def end(token) -> None:
    if token is not None:
        token.__exit__(None, None, None)


def count(name: str, value=1, shape=None) -> None:
    s = _SESSION
    if s is None:
        return
    s.registry.counter(name, shape=shape).add(value)


def gauge(name: str, value) -> None:
    s = _SESSION
    if s is None:
        return
    s.registry.gauge(name).set(value)


def observe(name: str, value) -> None:
    s = _SESSION
    if s is None:
        return
    s.registry.histogram(name).observe(value)


def spanned(name: str, plane: str = ""):
    """Method/function decorator: run the call under a span when on.

    Off-path cost is one global load + ``None`` check per call — no
    span object, no context manager, no tracer touch.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            s = _SESSION
            if s is None:
                return fn(*args, **kwargs)
            with s.tracer.span(name, plane=plane):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def profiled(name: str):
    """Kernel-dispatcher decorator: block-until-ready timing when on.

    With no active session (or ``profile_kernels=False``) the wrapper
    is a direct call — no timing, no blocking, no extra sync points, so
    the device pipeline's async launch overlap is untouched by default.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            s = _SESSION
            if s is None or not s.profile_kernels:
                return fn(*args, **kwargs)
            return s.profile_call(name, fn, *args, **kwargs)

        return wrapper

    return deco
