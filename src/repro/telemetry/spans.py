"""Span tracer: nested wall-clock intervals on per-PE tracks.

A span is a named interval (``perf_counter`` seconds relative to the
tracer's origin) on a *track* — ``pe=-1`` is the host/driver track,
``pe >= 0`` a trainer PE. Tracks carry independent nesting stacks, so
``step > sample > kernel.gather_rows`` nests naturally and the
exporter can emit Chrome-trace complete events per track.

Each finished span records its *inclusive* duration and the summed
duration of its direct children (``child_s``); the difference is its
*exclusive* (self) time, which is what per-plane breakdowns sum so
that a plane's seconds are never double-counted against its callees'.
"""

from __future__ import annotations

import time

__all__ = ["Span", "SpanTracer"]


class Span:
    """One timed interval; use as a context manager via ``tracer.span``."""

    __slots__ = (
        "name",
        "plane",
        "pe",
        "t0",
        "t1",
        "depth",
        "nbytes",
        "child_s",
        "_tracer",
    )

    def __init__(self, tracer: "SpanTracer", name: str, pe: int, plane: str, nbytes: int):
        self._tracer = tracer
        self.name = name
        self.plane = plane
        self.pe = pe
        self.nbytes = nbytes
        self.t0 = 0.0
        self.t1 = 0.0
        self.depth = 0
        self.child_s = 0.0

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    @property
    def self_s(self) -> float:
        """Exclusive time: inclusive duration minus direct children."""
        return max(self.duration - self.child_s, 0.0)

    def __enter__(self) -> "Span":
        self._tracer._enter(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer._exit(self)
        return False

    def as_row(self) -> dict:
        return {
            "name": self.name,
            "plane": self.plane,
            "pe": self.pe,
            "t0": self.t0,
            "t1": self.t1,
            "depth": self.depth,
            "nbytes": int(self.nbytes),
        }


class SpanTracer:
    """Collects finished spans; per-track stacks give nesting depth."""

    def __init__(self):
        self.spans: list[Span] = []
        self._stacks: dict[int, list[Span]] = {}
        self.origin = time.perf_counter()

    def span(self, name: str, pe: int = -1, plane: str = "", nbytes: int = 0) -> Span:
        return Span(self, name, pe, plane or name.split(".", 1)[0], nbytes)

    # -- context-manager protocol driven by Span ----------------------- #
    def _enter(self, span: Span) -> None:
        stack = self._stacks.setdefault(span.pe, [])
        span.depth = len(stack)
        stack.append(span)
        span.t0 = time.perf_counter() - self.origin

    def _exit(self, span: Span) -> None:
        span.t1 = time.perf_counter() - self.origin
        stack = self._stacks.get(span.pe)
        if stack and stack[-1] is span:
            stack.pop()
        elif stack and span in stack:
            # Mis-nested begin/end (an exception unwound past an open
            # begin token): drop everything above it rather than corrupt
            # the depth accounting for the rest of the run.
            while stack[-1] is not span:
                stack.pop()
            stack.pop()
        if stack:
            stack[-1].child_s += span.duration
        self.spans.append(span)

    # -- explicit begin/end (for loop bodies where `with` would force a
    #    large re-indent); telemetry-off callers get None tokens ------- #
    def begin(self, name: str, pe: int = -1, plane: str = "", nbytes: int = 0) -> Span:
        span = self.span(name, pe=pe, plane=plane, nbytes=nbytes)
        span.__enter__()
        return span

    def end(self, span: Span | None) -> None:
        if span is not None:
            span.__exit__(None, None, None)

    # -- aggregation --------------------------------------------------- #
    def by_name(self) -> dict:
        """``{name: {count, total_s}}`` over inclusive durations."""
        out: dict[str, dict] = {}
        for sp in self.spans:
            row = out.setdefault(sp.name, {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += sp.duration
        return out

    def by_plane(self) -> dict:
        """``{plane: self_seconds}`` — exclusive time, sums to <= wall."""
        out: dict[str, float] = {}
        for sp in self.spans:
            out[sp.plane] = out.get(sp.plane, 0.0) + sp.self_s
        return out

    def total_s(self) -> float:
        """Wall seconds covered by top-level spans."""
        return sum(sp.duration for sp in self.spans if sp.depth == 0)

    def summary(self) -> dict:
        names = self.by_name()
        return {
            "span_count": len(self.spans),
            "total_s": self.total_s(),
            "by_plane": dict(sorted(self.by_plane().items())),
            "by_name": {k: names[k] for k in sorted(names)},
        }
